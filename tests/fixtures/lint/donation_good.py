"""Fixture: the rebind-in-place donation idiom the backends use."""
import jax


def _step(params, buf):
    return buf + 1, buf * 0


class Runner:
    def __init__(self):
        self.step = jax.jit(_step, donate_argnums=(1,))
        self.buf = None

    def run_local(self, params, buf):
        out, buf = self.step(params, buf)     # rebound by the call stmt
        return out + buf

    def run_attr(self, params):
        out, self.buf = self.step(params, self.buf)
        return out + self.buf

    def run_temp(self, params, buf):
        # donating a temporary (not a named variable) is always fine
        out, _ = self.step(params, buf * 2)
        return out + buf
