"""Fixture: kernel-safety violations (all flagged)."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bad_kernel(x_ref, o_ref, acc_scr):
    i = pl.program_id(0)
    if i == 0:                                     # python branch on tracer
        acc_scr[...] = jnp.zeros_like(acc_scr)     # unguarded store
    o_ref[...] = acc_scr[...] + x_ref[...]         # unguarded store


def misaligned_spec():
    return pl.BlockSpec((4, 100), lambda i: (i, 0))   # 4 % 8, 100 % 128
