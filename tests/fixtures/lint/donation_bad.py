"""Fixture: buffers read after being donated (all flagged)."""
import jax


def _step(params, buf):
    return buf + 1


class Runner:
    def __init__(self):
        self.step = jax.jit(_step, donate_argnums=(1,))
        self.buf = None

    def run_local(self, params, buf):
        out = self.step(params, buf)
        return out + buf              # buf is dead after the call

    def run_attr(self, params):
        out = self.step(params, self.buf)
        return out + self.buf         # self.buf is dead after the call
