"""Fixture: dicts that merely LOOK close to envelopes (none flagged)."""


def kv_entry(k_pool, v_pool):
    # the KV pools: "v" binds an array, not a version string
    return {"k": k_pool, "v": v_pool}


def feature_flags():
    return {"v": False, "hedge": True}   # bool, not a version tag


def typed_send(ep, schemas, req):
    return ep.execute("generate", schemas.to_wire(req))
