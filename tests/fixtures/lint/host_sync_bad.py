"""Fixture: host syncs inside jit-reachable code (all flagged)."""
from functools import partial

import jax
import numpy as np


@jax.jit
def bad_step(x):
    n = int(x)                        # coercion forces a host sync
    y = np.asarray(x)                 # host materialization
    z = x.item()                      # host sync
    return n + y + z


def _inner(v):
    jax.device_get(v)                 # explicit transfer
    return v.block_until_ready()      # dispatch stall


step2 = jax.jit(partial(_inner))
