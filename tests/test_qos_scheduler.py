"""QoS scheduling: policy units, engine preemption/restore, DES mirror,
federation tie-break, and the abort-mid-prefill reclaim regression.

The tentpole contract under test: admission/ordering/eviction decisions
live in ``serving/scheduler.py``; the engine supplies mechanics only.
FCFS must be bit-identical to the pre-refactor queue (the cross-backend
parity matrix covers that); preempted-and-restored sequences must be
token-identical to uninterrupted runs on every restore path.
"""
import copy

import numpy as np
import pytest

from repro.serving.request import InferenceRequest, SamplingParams
from repro.serving.scheduler import (EDFPolicy, FCFSPolicy, PriorityPolicy,
                                     make_policy)


def _req(rid, qos="interactive", priority=0, deadline=None, plen=4,
         max_tokens=8):
    return InferenceRequest(
        model="m", prompt_tokens=list(range(2, 2 + plen)), request_id=rid,
        qos=qos, priority=priority, deadline=deadline,
        sampling=SamplingParams(max_tokens=max_tokens))


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------


def test_fcfs_is_arrival_order():
    p = FCFSPolicy()
    for rid in ("a", "b", "c"):
        p.add(_req(rid))
    assert [r.request_id for r in p.snapshot()] == ["a", "b", "c"]
    assert p.peek().request_id == "a"
    assert p.pop().request_id == "a"
    assert p.remove("c") is not None and len(p) == 1
    assert p.select_victim(_req("x"), [("b", _req("b"), 3, 0)]) is None


def test_priority_orders_by_class_then_priority_then_arrival():
    p = PriorityPolicy()
    p.add(_req("b0", qos="batch"))
    p.add(_req("i1", qos="interactive", priority=1))
    p.add(_req("i0", qos="interactive", priority=0))
    p.add(_req("b1", qos="batch"))
    assert [r.request_id for r in p.snapshot()] == ["i0", "i1", "b0", "b1"]
    assert p.pop().request_id == "i0"


def test_priority_token_budgets_gate_admission():
    # batch budget covers ONE request (4 prompt + 8 max_tokens = 12)
    p = PriorityPolicy(token_budgets={"batch": 12})
    p.add(_req("b0", qos="batch"))
    p.add(_req("b1", qos="batch"))
    first = p.pop()
    p.on_admitted(first)
    assert p.peek() is None          # class over budget, b1 must wait
    assert len(p) == 1               # ... but it is still queued
    p.on_released(first)
    assert p.peek().request_id == "b1"


def test_priority_budget_never_strands_oversized_request():
    # a request bigger than its class's whole budget must still admit when
    # the class is idle — budgets cap concurrency, they never make a
    # request permanently inadmissible (the engine would spin forever)
    p = PriorityPolicy(token_budgets={"batch": 5})
    big = _req("big", qos="batch")           # 4 + 8 = 12 tokens > 5
    p.add(big)
    assert p.peek() is big
    p.on_admitted(p.pop())
    p.add(_req("b2", qos="batch"))
    assert p.peek() is None                  # class busy and over budget
    p.on_released(big)
    assert p.peek().request_id == "b2"


def test_priority_requeue_puts_victims_before_fresh_arrivals():
    p = PriorityPolicy()
    p.add(_req("b0", qos="batch"))
    victim = _req("bv", qos="batch")
    p.requeue(victim)
    assert [r.request_id for r in p.snapshot()] == ["bv", "b0"]


def test_priority_victim_rotation():
    p = PriorityPolicy()
    head = _req("i0", qos="interactive")
    running = [("b0", _req("b0", qos="batch"), 5, 1),
               ("b1", _req("b1", qos="batch"), 3, 0),
               ("i9", _req("i9", qos="interactive"), 2, 0)]
    # b1 has fewer preemptions than b0; the interactive peer is never a
    # victim for an interactive head
    assert p.select_victim(head, running) == "b1"
    # page pressure (head=None): still the least-evicted batch entry
    assert p.select_victim(None, running) == "b1"
    # batch head cannot displace batch peers
    assert p.select_victim(_req("b9", qos="batch"), running) is None


def test_edf_orders_by_deadline_none_last():
    p = EDFPolicy()
    p.add(_req("late", deadline=9.0))
    p.add(_req("none"))
    p.add(_req("soon", deadline=1.0))
    assert [r.request_id for r in p.snapshot()] == ["soon", "late", "none"]
    head = _req("h", deadline=2.0)
    running = [("a", _req("a", deadline=3.0), 1, 0),
               ("b", _req("b", deadline=8.0), 1, 0),
               ("c", _req("c", deadline=1.0), 1, 0)]
    assert p.select_victim(head, running) == "b"   # latest deadline
    # nothing later than the head -> no victim
    assert p.select_victim(_req("h2", deadline=99.0), running) is None


def test_edf_requeue_puts_victims_before_fresh_same_deadline():
    p = EDFPolicy()
    p.add(_req("f0"))
    p.add(_req("f1"))
    p.requeue(_req("victim"))                # same (no) deadline: victim first
    assert [r.request_id for r in p.snapshot()] == ["victim", "f0", "f1"]
    p.add(_req("soon", deadline=1.0))        # an earlier deadline still wins
    assert p.peek().request_id == "soon"


def test_make_policy_dispatch():
    assert isinstance(make_policy(None), FCFSPolicy)
    assert isinstance(make_policy("edf"), EDFPolicy)
    inst = PriorityPolicy()
    assert make_policy(inst) is inst
    with pytest.raises(ValueError):
        make_policy("lifo")


# ---------------------------------------------------------------------------
# engine: preemption + restore (real JAX, tiny model)
# ---------------------------------------------------------------------------


ENG_KW = dict(max_slots=3, max_seq_len=96, page_size=16)


def _solo_req(vocab, sampling_kw, rid="solo", plen=20, max_tokens=24,
              qos="batch"):
    rng = np.random.default_rng(11)
    return InferenceRequest(
        model="m", prompt_tokens=rng.integers(2, vocab, size=plen).tolist(),
        request_id=rid, qos=qos,
        sampling=SamplingParams(max_tokens=max_tokens, seed=5, **sampling_kw))


@pytest.mark.parametrize("restore_path", ["prefix-cache", "recompute",
                                          "swap"])
def test_preempt_restore_token_identity(llama, sampling, restore_path,
                                        engine_factory):
    """A preempted-and-restored sequence emits the exact token stream of an
    uninterrupted run, on all three restore paths (greedy AND seeded
    top-p via the sampling axis)."""
    cfg, model, params = llama
    kw = dict(ENG_KW)
    kw["enable_prefix_cache"] = restore_path == "prefix-cache"
    kw["preempt_swap"] = restore_path == "swap"
    req = _solo_req(cfg.vocab_size, sampling)
    ref_eng = engine_factory(model, params, **kw)
    ref_eng.add_request(copy.deepcopy(req))
    ref = ref_eng.run_to_completion()[0].output_tokens

    eng = engine_factory(model, params, scheduling_policy="priority",
                         enable_preemption=True, **kw)
    eng.add_request(copy.deepcopy(req))
    outs = []
    for _ in range(6):
        outs += eng.step()
    assert eng.preempt("solo")
    assert eng.num_running == 0 and eng.num_waiting == 1
    while eng.has_work():
        outs += eng.step()
    assert outs[0].output_tokens == ref
    assert eng.stats["preemptions"] == 1 and eng.stats["restores"] == 1
    if restore_path == "swap":
        assert eng.stats["swap_outs"] == 1 and eng.stats["swap_ins"] == 1
    if restore_path == "prefix-cache":
        # the victim's published pages came back out of the LRU
        assert eng.stats["restore_cached_tokens"] > 0


def test_blocked_interactive_preempts_batch(llama, engine_factory,
                                            request_factory):
    """Batch flood fills every slot; an interactive arrival evicts a batch
    victim instead of waiting for the drain, and every request still
    finishes exactly once."""
    cfg, model, params = llama
    eng = engine_factory(model, params, scheduling_policy="priority",
                         enable_preemption=True, enable_prefix_cache=True,
                         **ENG_KW)
    batch = request_factory(cfg.vocab_size, n=3, plen=10, max_tokens=40,
                            ramp=False)
    for r in batch:
        r.qos = "batch"
        eng.add_request(r)
    for _ in range(4):
        eng.step()
    assert eng.num_running == 3
    inter = _solo_req(cfg.vocab_size, dict(temperature=0.0), rid="int0",
                      plen=8, max_tokens=4, qos="interactive")
    eng.add_request(inter)
    eng.step()
    assert eng.stats["preemptions"] == 1
    assert "int0" in eng.running       # admitted by evicting a victim
    outs = eng.run_to_completion()
    assert sorted(o.request_id for o in outs) == \
        sorted([r.request_id for r in batch] + ["int0"])
    assert eng.stats["restores"] == 1
    int_out = next(o for o in outs if o.request_id == "int0")
    assert int_out.metrics.preemptions == 0


def test_fcfs_never_preempts_even_when_enabled(llama, engine_factory,
                                               request_factory):
    cfg, model, params = llama
    eng = engine_factory(model, params, scheduling_policy="fcfs",
                         enable_preemption=True, **ENG_KW)
    batch = request_factory(cfg.vocab_size, n=3, plen=10, max_tokens=30,
                            ramp=False)
    for r in batch:
        r.qos = "batch"
        eng.add_request(r)
    for _ in range(3):
        eng.step()
    eng.add_request(_solo_req(cfg.vocab_size, dict(temperature=0.0),
                              rid="int0", plen=8, max_tokens=4,
                              qos="interactive"))
    outs = eng.run_to_completion()
    assert eng.stats["preemptions"] == 0
    assert len(outs) == 4


def test_page_pressure_preemption_avoids_out_of_pages(llama,
                                                      engine_factory):
    """A pool too small for every growing sequence: without preemption the
    decode append raises OutOfPages; with it, a victim is shed and
    everything completes."""
    from repro.serving.kv_cache import OutOfPages
    cfg, model, params = llama
    kw = dict(max_slots=3, max_seq_len=64, page_size=8, num_pages=12,
              enable_prefix_cache=False)
    reqs = [_solo_req(cfg.vocab_size, dict(temperature=0.0), rid=f"b{i}",
                      plen=8, max_tokens=24, qos="batch") for i in range(3)]
    eng = engine_factory(model, params, **kw)
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    with pytest.raises(OutOfPages):
        eng.run_to_completion()
    eng = engine_factory(model, params, scheduling_policy="priority",
                         enable_preemption=True, **kw)
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    outs = eng.run_to_completion()
    assert len(outs) == 3
    assert eng.stats["preemptions"] > 0


def test_qos_token_budget_caps_batch_admissions(llama, engine_factory,
                                                request_factory):
    cfg, model, params = llama
    # budget covers one batch request (10 + 12 = 22 tokens)
    eng = engine_factory(model, params, scheduling_policy="priority",
                         qos_token_budgets={"batch": 25}, **ENG_KW)
    batch = request_factory(cfg.vocab_size, n=3, plen=10, max_tokens=12,
                            ramp=False)
    for r in batch:
        r.qos = "batch"
        eng.add_request(r)
    eng.step()
    assert eng.num_running == 1          # slots free, budget says no
    assert eng.num_waiting == 2
    outs = eng.run_to_completion()       # budget frees as requests finish
    assert len(outs) == 3


def test_preempt_restore_with_spec_decode(llama, engine_factory,
                                          request_factory):
    """Preemption composes with speculative decoding: the draft mirror is
    rebuilt on restore and the stream stays identical to an uninterrupted
    speculative run."""
    cfg, model, params = llama
    kw = dict(ENG_KW, spec_tokens=3, draft=(model, params))
    req = _solo_req(cfg.vocab_size, dict(temperature=0.8, top_p=0.9),
                    max_tokens=20)
    ref_eng = engine_factory(model, params, **kw)
    ref_eng.add_request(copy.deepcopy(req))
    ref = ref_eng.run_to_completion()[0].output_tokens

    eng = engine_factory(model, params, scheduling_policy="priority",
                         enable_preemption=True, **kw)
    eng.add_request(copy.deepcopy(req))
    outs = []
    for _ in range(3):
        outs += eng.step()
    assert eng.preempt("solo")
    while eng.has_work():
        outs += eng.step()
    assert outs[0].output_tokens == ref
    assert eng.stats["restores"] == 1


# ---------------------------------------------------------------------------
# abort mid-chunked-prefill reclaims everything (satellite regression)
# ---------------------------------------------------------------------------


def _assert_backend_clean(backend, max_slots):
    kv = backend.kv
    assert len(backend.slot_of) == 0
    assert sorted(backend.free_slots) == list(range(max_slots))
    assert backend.decoding == set()
    assert kv._tables == {} and kv._lens == {}
    assert kv._ref == {}                   # no refcount survives a full free
    # every non-trash page is claimable again (plain free or LRU-parked)
    assert kv.free_pages == kv.num_pages - 1


def test_abort_mid_chunked_prefill_frees_all_pages(llama, engine_factory,
                                                   shared_prefix_prompts):
    """Abort during a chunked prefill must free the slot, every page —
    including prefix-cache refs pinned at admission — and the spec-decode
    draft mirror state."""
    cfg, model, params = llama
    prompts = shared_prefix_prompts(cfg.vocab_size, 2, n_shared=32,
                                    n_tail=16)
    eng = engine_factory(model, params, enable_prefix_cache=True,
                         chunked_prefill_budget=8,
                         spec_tokens=2, draft=(model, params), **ENG_KW)
    # seed the prefix cache with a completed twin, then free it (its pages
    # park in the LRU)
    r0 = InferenceRequest(model="m", prompt_tokens=prompts[0],
                          request_id="twin",
                          sampling=SamplingParams(max_tokens=3))
    eng.add_request(r0)
    eng.run_to_completion()
    lru_before = eng.backend.kv.cached_free_pages
    assert lru_before > 0
    # admit a same-prefix request; abort it mid-chunked-prefill while it
    # holds resurrected shared pages + fresh pages + a draft-mirror slot
    r1 = InferenceRequest(model="m", prompt_tokens=prompts[1],
                          request_id="victim",
                          sampling=SamplingParams(max_tokens=3))
    eng.add_request(r1)
    eng.step()
    assert "victim" in eng.prefilling      # still ingesting its prompt
    assert eng.abort("victim")
    assert not eng.has_work()
    _assert_backend_clean(eng.backend, eng.cfg.max_slots)
    # the draft mirror (no prefix cache) must have reclaimed slot + pages
    _assert_backend_clean(eng.draft_backend, eng.cfg.max_slots)
    # a later same-prefix request still hits the published pages and runs
    r2 = InferenceRequest(model="m", prompt_tokens=prompts[1],
                          request_id="again",
                          sampling=SamplingParams(max_tokens=3))
    eng.add_request(r2)
    outs = eng.run_to_completion()
    assert len(outs) == 1 and outs[0].finish_reason
    assert outs[0].metrics.cached_prompt_tokens > 0


def test_abort_waiting_and_preempted_requests(llama, engine_factory):
    cfg, model, params = llama
    eng = engine_factory(model, params, scheduling_policy="priority",
                         enable_preemption=True, enable_prefix_cache=True,
                         **ENG_KW)
    # abort while waiting
    eng.add_request(_solo_req(cfg.vocab_size, dict(temperature=0.0),
                              rid="w0"))
    assert eng.abort("w0") and not eng.has_work()
    # abort while preempted (queued victim with saved state)
    eng.add_request(_solo_req(cfg.vocab_size, dict(temperature=0.0),
                              rid="p0"))
    for _ in range(3):
        eng.step()
    assert eng.preempt("p0")
    assert eng.abort("p0")
    assert not eng.has_work() and eng._preempted == {}
    _assert_backend_clean(eng.backend, eng.cfg.max_slots)


# ---------------------------------------------------------------------------
# DES mirror: SimEngine / ModelDeployment QoS ordering
# ---------------------------------------------------------------------------


def _sim_waits(policy, preempt, n_batch=6, n_interactive=4):
    from repro.core.clock import EventLoop, VirtualClock
    from repro.core.instances import SimEngine, SimRequest
    from repro.core.testbed import LLAMA70B
    from repro.serving.costmodel import InstanceCost

    loop = EventLoop(VirtualClock())
    eng = SimEngine(loop, InstanceCost(cfg=LLAMA70B), max_slots=2,
                    scheduling_policy=policy, enable_preemption=preempt,
                    restore_hit_rate=0.9)
    waits = {"batch": [], "interactive": []}

    def submit(sreq, t):
        def _go():
            eng.submit(sreq,
                       lambda ft, s=sreq, t0=t: waits[s.qos].append(ft - t0),
                       None)
        loop.call_at(t, _go)

    for j in range(n_batch):
        submit(SimRequest(f"b{j}", 256, 400, qos="batch"), 0.0)
    for j in range(n_interactive):
        submit(SimRequest(f"i{j}", 64, 16, qos="interactive"), 5.0 + j)
    loop.run_until_idle()
    assert len(waits["batch"]) == n_batch
    assert len(waits["interactive"]) == n_interactive
    return (sum(waits["interactive"]) / n_interactive,
            sum(waits["batch"]) / n_batch, eng)


def test_sim_engine_priority_orders_interactive_before_batch():
    i_fcfs, b_fcfs, _ = _sim_waits("fcfs", False)
    i_prio, b_prio, _ = _sim_waits("priority", False)
    i_pre, b_pre, eng = _sim_waits("priority", True)
    # qualitative QoS ordering: interactive waits less than batch under
    # the priority policies, and preemption improves it further
    assert i_prio < b_prio
    assert i_pre < b_pre
    assert i_prio < i_fcfs
    assert i_pre < i_prio
    assert eng.total_preemptions > 0


def test_sim_engine_edf_prefers_earliest_deadline():
    from repro.core.clock import EventLoop, VirtualClock
    from repro.core.instances import SimEngine, SimRequest
    from repro.core.testbed import LLAMA70B
    from repro.serving.costmodel import InstanceCost

    loop = EventLoop(VirtualClock())
    eng = SimEngine(loop, InstanceCost(cfg=LLAMA70B), max_slots=1,
                    scheduling_policy="edf")
    firsts = {}
    # the dummy grabs the single slot immediately; the rest queue and the
    # EDF policy orders their admissions by deadline (None last)
    for rid, dl in (("dummy", None), ("loose", 500.0), ("none", None),
                    ("tight", 50.0)):
        eng.submit(SimRequest(rid, 64, 8, deadline=dl),
                   lambda t, r=rid: firsts.setdefault(r, t), None)
    loop.run_until_idle()
    assert firsts["tight"] <= firsts["loose"] <= firsts["none"]


def test_model_deployment_qos_end_to_end():
    """Gateway -> federation -> endpoint -> SimEngine: qos tags survive the
    whole path and the priority deployment serves interactive first."""
    from repro.core.testbed import (LLAMA70B, build_system,
                                    default_deployment)

    sysd = build_system(
        {"sophia": {LLAMA70B.name: default_deployment(
            LLAMA70B, max_slots=2, scheduling_policy="priority",
            enable_preemption=True, storage_bw=40e9)}},
        startup_delay=1.0)
    token = sysd.token_for("alice")
    futs = {}
    for j in range(4):
        futs[f"b{j}"] = sysd.gateway.submit(token, {
            "request_id": f"b{j}", "model": LLAMA70B.name,
            "prompt_tokens": 256, "max_tokens": 1500, "qos": "batch"})

    def later():
        futs["i0"] = sysd.gateway.submit(token, {
            "request_id": "i0", "model": LLAMA70B.name,
            "prompt_tokens": 32, "max_tokens": 8, "qos": "interactive"})

    # the 70B flood takes tens of simulated seconds per wave; the
    # interactive request lands mid-flood
    sysd.loop.call_at(20.0, later)
    sysd.loop.run_until_idle()
    assert all(f.done() and f.error is None for f in futs.values())
    recs = {r.request_id: r for r in sysd.metrics.records}
    # the interactive request finished long before the batch flood drained
    assert recs["i0"].finish < max(r.finish for r in recs.values())
    assert recs["i0"].e2e < min(recs[f"b{j}"].e2e for j in range(4))
    # the routing decision carries the qos tag
    assert any("qos=interactive" in d[3] for d in sysd.router.decisions)


# ---------------------------------------------------------------------------
# federation tie-break (satellite)
# ---------------------------------------------------------------------------


def test_federation_rule2_tiebreaks_by_queue_then_free_nodes():
    from repro.core.federation import FederationRouter

    class EP:
        def __init__(self, free, queued):
            self.deployments = {"m": type("D", (), {
                "nodes_per_instance": 1})()}
            self.scheduler = type("S", (), {
                "available_nodes": lambda s=None, f=free: f,
                "queue_depth": lambda s=None, q=queued: q})()

        def hosts(self, model):
            return True

        def model_states(self, model):
            return []

    # a: free nodes but deep queue; b: fewer free nodes, empty queue;
    # c: same queue as b, MORE free nodes -> c wins
    eps = {"a": EP(free=4, queued=3), "b": EP(free=1, queued=0),
           "c": EP(free=2, queued=0)}
    router = FederationRouter(eps, {"m": ["a", "b", "c"]})
    pick = router.select_endpoint("m", qos="interactive")
    model, ep, rule, detail = router.decisions[-1]
    assert pick == "c" and rule == "free-nodes"
    assert "queue_depth=0" in detail and "free_nodes=2" in detail
    assert "qos=interactive" in detail
