"""Chaos-hardened federation: heartbeat-driven failure detection, retry
budgets with backoff, circuit breakers, mid-stream failover resume, and
graceful brownout.

Covers the resilience state machines (``repro.core.resilience``) as units,
the heartbeat monitor's edge-triggered health feed (injected outages must
persist past monitor ticks), gateway end-to-end failover under noisy and
SILENT endpoint crashes (the stream resumes on another engine instead of
regenerating), brownout shedding, the real engine's ``resume_request``
parity, and a property over random chaos schedules: every admitted request
resolves exactly once, ok or with a /v1 taxonomy error.
"""
import random

import pytest

from repro.api import errors
from repro.api.client import FirstClient
from repro.core import EventLoop, GatewayConfig
from repro.core.gateway import RateLimiter
from repro.core.resilience import (BreakerPolicy, BrownoutController,
                                   BrownoutPolicy, CircuitBreaker,
                                   RetryBudget, RetryPolicy)
from repro.core.testbed import (LLAMA70B, build_system, default_deployment,
                                warm_up)

MODEL = LLAMA70B.name


def _system(clusters=("sophia", "polaris"), **gw):
    deps = {c: {MODEL: default_deployment(LLAMA70B)} for c in clusters}
    return build_system(deps, gateway_config=GatewayConfig(**gw))


def _resilient(clusters=("sophia", "polaris"), retry=None, **gw):
    # the TTFT bound must clear a cold start (~90s: job startup + a 70B
    # model load at storage bandwidth); the stall bound stays tight
    return _system(clusters,
                   retry=retry or RetryPolicy(max_attempts=3,
                                              attempt_timeout=300.0,
                                              stall_timeout=10.0),
                   breaker=BreakerPolicy(), **gw)


def _hot(sysd, endpoint_id):
    """Spawn a hot instance on a secondary endpoint (no cold start later)."""
    sysd.endpoints[endpoint_id]._spawn_instance(MODEL)
    sysd.loop.run_until(sysd.loop.now() + 120.0)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_monitor_does_not_override_injected_outage():
    """Regression: the old ``HealthMonitor._tick`` rewrote EVERY endpoint's
    health each interval, silently healing injected outages between ticks.
    Detection is edge-triggered now: an outage injected at the router
    persists for its full duration even while heartbeats keep flowing."""
    sysd = _system()
    warm_up(sysd, MODEL)
    t0 = sysd.loop.now()
    sysd.faults.endpoint_outage(sysd.router, "sophia-ep", t=t0 + 1.0,
                                duration=100.0)
    # several monitor ticks (interval 15s) pass while beats still arrive
    sysd.loop.run_until(t0 + 60.0)
    assert sysd.health.checks >= 4
    assert sysd.health.is_up("sophia-ep")          # monitor's OWN belief
    assert sysd.router._healthy["sophia-ep"] is False   # outage persists
    assert sysd.router.select_endpoint(MODEL) == "polaris-ep"
    sysd.loop.run_until(t0 + 120.0)                # outage expires
    assert sysd.router._healthy["sophia-ep"] is True


def test_rate_limiter_zero_rate_is_drain_only():
    """Regression: ``rate_limit_per_user=0.0`` used to ZeroDivisionError in
    the denial path. A zero rate is a valid drain-only bucket: the burst is
    spendable, then every denial carries retry_after=inf."""
    loop = EventLoop()
    rl = RateLimiter(loop, rate=0.0, burst=2.0)
    assert rl.acquire("u") == (True, 0.0)
    assert rl.acquire("u")[0]
    ok, wait = rl.acquire("u")
    assert not ok and wait == float("inf")

    sysd = _system(clusters=("sophia",), rate_limit_per_user=0.0,
                   rate_burst=1.0)
    warm_up(sysd, MODEL)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    f1 = client.chat(model=MODEL, prompt_tokens=8, max_tokens=2)
    f2 = client.chat(model=MODEL, prompt_tokens=8, max_tokens=2)
    sysd.loop.run_until_idle()
    assert f1.error is None
    assert isinstance(f2.error, errors.RateLimitError)
    assert f2.error.retry_after == float("inf")
    assert f2.error.to_dict()["error"]["retry_after"] == float("inf")


def test_jobs_status_cold_model_reports_full_shape():
    """Regression: the cold-model fallback emitted only {endpoint, state},
    so dashboards indexing healthy/queue_depth/free_nodes crashed on any
    model with zero live instances."""
    sysd = _system()
    (entry,) = sysd.gateway.jobs_status()[MODEL]
    assert entry["state"] == "cold"
    assert entry["endpoint"] == "sophia-ep"
    assert entry["healthy"] is True
    assert entry["queue_depth"] == 0
    assert entry["free_nodes"] == 24
    assert entry["load"] == 0


# ---------------------------------------------------------------------------
# heartbeat-driven detection
# ---------------------------------------------------------------------------

def test_heartbeats_detect_crash_and_recovery():
    """Liveness is observed, not scripted: a crashed endpoint stops
    beating and is marked down after ``miss_threshold`` beat intervals of
    silence; the FIRST beat after restart marks it up again."""
    sysd = _system()
    warm_up(sysd, MODEL)
    ep = sysd.endpoints["sophia-ep"]
    t0 = sysd.loop.now()
    sysd.faults.crash_endpoint(ep, t=t0 + 1.0, duration=60.0)
    sysd.loop.run_until(t0 + 40.0)
    assert ep.stats["crashes"] == 1
    assert not sysd.health.is_up("sophia-ep")
    assert sysd.router._healthy["sophia-ep"] is False
    events = [e for _, epid, e in sysd.health.transitions
              if epid == "sophia-ep"]
    assert "down" in events
    sysd.loop.run_until(t0 + 80.0)                 # recovered at t0+61
    assert ep.stats["recoveries"] == 1
    assert sysd.health.is_up("sophia-ep")
    assert sysd.router._healthy["sophia-ep"] is True
    events = [e for _, epid, e in sysd.health.transitions
              if epid == "sophia-ep"]
    assert events[-1] == "up"


def test_heartbeat_loss_false_positive_self_heals():
    """Beats vanish while the endpoint keeps serving (a detector false
    positive): the monitor marks it down, and recovery needs no operator
    action — the first beat after the window restores health."""
    sysd = _system()
    warm_up(sysd, MODEL)
    ep = sysd.endpoints["sophia-ep"]
    t0 = sysd.loop.now()
    sysd.faults.heartbeat_loss(ep, t=t0 + 1.0, duration=60.0)
    # a tick lands at latest 15s after the silence threshold (t0+16)
    sysd.loop.run_until(t0 + 35.0)
    assert ep.up                                   # it never actually died
    assert not sysd.health.is_up("sophia-ep")
    assert sysd.router._healthy["sophia-ep"] is False
    sysd.loop.run_until(t0 + 80.0)
    assert sysd.health.is_up("sophia-ep")
    assert sysd.router._healthy["sophia-ep"] is True


def test_latency_injection_flags_straggler_and_demotes_it():
    """Beat latency over the EWMA threshold raises the router's straggler
    flag: the endpoint stays eligible but loses every tie-break, so traffic
    drains to the prompt replica; the flag clears as the EWMA decays."""
    sysd = _system()
    warm_up(sysd, MODEL)                           # sophia hot
    _hot(sysd, "polaris-ep")                       # polaris hot too
    assert sysd.router.select_endpoint(MODEL) == "sophia-ep"
    t0 = sysd.loop.now()
    sysd.faults.latency_injection(sysd.endpoints["sophia-ep"], t=t0 + 1.0,
                                  duration=60.0, extra=5.0)
    sysd.loop.run_until(t0 + 40.0)
    assert sysd.router._slow.get("sophia-ep") is True
    assert sysd.router.select_endpoint(MODEL) == "polaris-ep"
    events = [e for _, epid, e in sysd.health.transitions
              if epid == "sophia-ep"]
    assert "slow" in events
    sysd.loop.run_until(t0 + 150.0)                # EWMA decays back down
    assert sysd.router._slow.get("sophia-ep") is False
    assert "recovered-speed" in [
        e for _, epid, e in sysd.health.transitions if epid == "sophia-ep"]
    assert sysd.router.select_endpoint(MODEL) == "sophia-ep"


# ---------------------------------------------------------------------------
# resilience primitives (units)
# ---------------------------------------------------------------------------

def test_circuit_breaker_state_machine():
    b = CircuitBreaker("ep", BreakerPolicy(fail_threshold=3, cooldown=10.0,
                                           max_cooldown=40.0))
    b.on_failure(0.0)
    b.on_failure(0.0)
    assert b.state == "closed" and not b.blocked(0.0)
    b.on_failure(0.0)                              # third consecutive: trip
    assert b.state == "open" and b.opens == 1
    assert b.blocked(5.0) and not b.allow(5.0)
    assert b.allow(10.0)                           # cooldown over: one probe
    assert b.state == "half_open"
    assert not b.allow(10.0)                       # single probe at a time
    assert b.blocked(10.0)
    b.on_failure(10.0)                             # probe failed: escalate
    assert b.state == "open" and b.opens == 2
    assert not b.allow(25.0)                       # cooldown doubled to 20s
    assert b.allow(30.0)
    b.on_success(30.0)                             # probe ok: close, reset
    assert b.state == "closed"
    assert b.snapshot(30.0)["cooldown"] == 10.0


def test_circuit_breaker_timeout_rate_trip():
    b = CircuitBreaker("ep", BreakerPolicy(fail_threshold=100,
                                           timeout_rate=0.5, min_samples=4,
                                           window=60.0))
    b.on_success(0.0)
    b.on_failure(1.0, timeout=True)
    b.on_failure(2.0, timeout=True)
    assert b.state == "closed"                     # below min_samples
    b.on_failure(3.0, timeout=True)                # 3/4 timeouts > 0.5
    assert b.state == "open"


def test_retry_policy_backoff_and_deadline_timeouts():
    p = RetryPolicy(max_attempts=4, base_backoff=1.0, max_backoff=4.0)
    rng = random.Random(0)
    assert all(0.0 <= p.backoff(0, rng) <= 1.0 for _ in range(50))
    assert all(0.0 <= p.backoff(5, rng) <= 4.0 for _ in range(50))
    p = RetryPolicy(max_attempts=3, attempt_timeout=30.0,
                    min_attempt_timeout=0.25)
    # a 9s TTFT deadline splits across the remaining attempts
    assert p.timeout_for(0, now=0.0, deadline=9.0) == pytest.approx(3.0)
    assert p.timeout_for(2, now=0.0, deadline=9.0) == pytest.approx(9.0)
    assert p.timeout_for(0, now=0.0, deadline=None) == 30.0
    # nearly-spent deadline still leaves the floor
    assert p.timeout_for(0, now=100.0, deadline=100.3) == 0.25


def test_retry_budget_bounds_amplification():
    b = RetryBudget(ratio=0.5, floor=1.0, cap=2.0)
    assert b.try_withdraw()                        # the floor is spendable
    assert not b.try_withdraw()
    assert b.denied == 1
    b.on_request()
    b.on_request()                                 # 2 deposits x 0.5
    assert b.try_withdraw()
    assert b.withdrawals == 2 and b.deposits == 2
    for _ in range(100):
        b.on_request()
    assert b.balance <= b.cap


def test_brownout_ladder_steps_with_hysteresis():
    c = BrownoutController(BrownoutPolicy(enter_pressure=0.7,
                                          exit_pressure=0.3, dwell=10.0))
    assert c.observe(0.9, 0.0) == 1
    assert c.observe(0.9, 5.0) == 1                # dwell holds it
    assert c.observe(0.9, 10.0) == 2
    assert c.observe(0.5, 20.0) == 2               # between thresholds
    assert c.observe(0.9, 30.0) == 3
    assert c.observe(0.9, 45.0) == 3               # MAX_LEVEL
    assert c.shed_batch() and c.suppress_hedges()
    assert c.effective_attempts(4) == 1
    assert c.admission_cap(64) == 256
    assert c.observe(0.1, 55.0) == 2
    assert c.effective_attempts(4) == 2
    assert c.admission_cap(64) is None
    assert c.observe(0.1, 65.0) == 1
    assert c.observe(0.1, 75.0) == 0
    assert not c.shed_batch()
    assert len(c.transitions) == 6


# ---------------------------------------------------------------------------
# gateway end-to-end: failover resume, timeouts, breakers, brownout
# ---------------------------------------------------------------------------

def _crash_failover(silent):
    sysd = _resilient()
    warm_up(sysd, MODEL)
    _hot(sysd, "polaris-ep")
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    fut, asm = client.stream(model=MODEL, prompt_tokens=64, max_tokens=200,
                             request_id="x1")
    # kill the serving endpoint mid-decode (the +4s offset clears alice's
    # 2s auth introspection and lands with tokens already streamed)
    sysd.faults.crash_endpoint(sysd.endpoints["sophia-ep"],
                               t=sysd.loop.now() + 4.0, duration=600.0,
                               silent=silent)
    sysd.loop.run_until_idle()
    return sysd, fut, asm


@pytest.mark.parametrize("silent", [False, True],
                         ids=["noisy-crash", "silent-crash"])
def test_midstream_crash_fails_over_and_resumes(silent):
    """Mid-stream endpoint death: the retry layer resubmits to the other
    cluster carrying the already-streamed token count, and the new engine
    RESUMES via restore (chunked prefill of prompt+generated) instead of
    regenerating. The client sees a gap, then the remaining tokens — no
    duplicate, no loss. A silent crash (futures dropped, no error) must be
    caught by the stall timeout instead of an error callback."""
    sysd, fut, asm = _crash_failover(silent)
    assert fut.error is None
    resp = fut.result()
    assert resp.endpoint_id == "polaris-ep"
    assert asm.finished
    # exactly max_tokens delivered: offset dedupe + resume, never replay
    assert asm.n_tokens == resp.usage.completion_tokens == 200
    rec = next(r for r in sysd.metrics.records if r.request_id == "x1")
    assert rec.stream_frames == 200                # each token seen ONCE
    assert rec.attempts == 2
    assert rec.resumed_tokens > 0
    assert sysd.metrics.retries == 1
    assert sysd.metrics.failovers_resumed == 1
    assert sysd.metrics.resumed_tokens == rec.resumed_tokens
    if silent:
        # no error ever arrived: only the stall timer could notice
        assert sysd.metrics.timeouts == 1 and rec.timeouts == 1
    # the resuming engine restored, not regenerated: its resumed-token
    # counter carries exactly what the client already held
    pol = sysd.endpoints["polaris-ep"].instances[MODEL][0]
    assert pol.engine.total_resumed_tokens == rec.resumed_tokens
    st = sysd.gateway.jobs_status()["_gateway"]
    assert st["failovers_resumed"] == 1
    assert st["resumed_tokens"] == rec.resumed_tokens
    if silent:
        assert st["timeouts"] == 1


def test_breaker_trips_fails_fast_and_recovers_via_probe():
    """Repeated failures open the endpoint's breaker: later requests are
    excluded from routing up front (fail fast, no dispatch). After the
    cooldown one half-open probe goes through; its success closes the
    breaker and traffic returns."""
    sysd = _system(clusters=("sophia",),
                   retry=RetryPolicy(max_attempts=2, base_backoff=0.2,
                                     max_backoff=0.5, attempt_timeout=300.0),
                   breaker=BreakerPolicy(fail_threshold=3, cooldown=30.0))
    warm_up(sysd, MODEL)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    t0 = sysd.loop.now()
    sysd.faults.crash_endpoint(sysd.endpoints["sophia-ep"], t=t0 + 1.0,
                               duration=120.0)
    futs = []
    for i in range(5):
        sysd.loop.call_at(t0 + 3.0 + i, lambda i=i: futs.append(
            client.chat(model=MODEL, prompt_tokens=8, max_tokens=2,
                        request_id=f"b{i}")))
    sysd.loop.run_until_idle()
    assert len(futs) == 5
    # all five failed with taxonomy errors, and the breaker tripped
    assert all(isinstance(f.error, errors.APIError) for f in futs)
    assert sysd.metrics.breaker_opens >= 1
    b = sysd.gateway.breakers["sophia-ep"]
    assert b.state == "open"
    st = sysd.gateway.jobs_status()["_gateway"]
    assert st["breakers"]["sophia-ep"]["state"] == "open"
    assert st["breaker_opens"] == sysd.metrics.breaker_opens
    # endpoint recovers at t0+121; past the cooldown the next request is
    # the half-open probe — it succeeds (cold start) and closes the breaker
    sysd.loop.run_until(t0 + 140.0)
    probe = client.chat(model=MODEL, prompt_tokens=8, max_tokens=2)
    sysd.loop.run_until_idle()
    assert probe.error is None
    assert b.state == "closed"


def test_brownout_sheds_batch_then_recovers():
    """Losing all healthy capacity drives the pressure signal to 1.0: the
    ladder steps to its deepest level (batch shed, hedges off, retries off,
    admission tightened), reports itself in jobs_status, and unwinds one
    level per dwell once capacity returns."""
    sysd = _system(clusters=("sophia",),
                   brownout=BrownoutPolicy(enter_pressure=0.7,
                                           exit_pressure=0.3, dwell=10.0,
                                           eval_interval=5.0))
    warm_up(sysd, MODEL)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    t0 = sysd.loop.now()
    sysd.faults.crash_endpoint(sysd.endpoints["sophia-ep"], t=t0 + 1.0,
                               duration=120.0)
    sysd.loop.run_until(t0 + 70.0)      # detection + 3 dwell periods
    assert sysd.gateway.brownout.level == 3
    shed = client.chat(model=MODEL, prompt_tokens=8, max_tokens=2,
                       qos="batch")
    sysd.loop.run_until_idle()
    assert isinstance(shed.error, errors.DegradedError)
    assert shed.error.retry_after == 10.0
    st = sysd.gateway.jobs_status()["_gateway"]
    assert st["degradation_level"] == 3
    assert st["degradation"]["step"] == "no-retries/tight-admission"
    assert sysd.metrics.brownout_shed >= 1
    assert sysd.metrics.rejections["degraded"] >= 1
    # capacity returns at t0+121: the ladder unwinds and batch is admitted
    sysd.loop.run_until(t0 + 200.0)
    assert sysd.gateway.brownout.level == 0
    ok = client.chat(model=MODEL, prompt_tokens=8, max_tokens=2, qos="batch")
    sysd.loop.run_until_idle()
    assert ok.error is None


# ---------------------------------------------------------------------------
# real engine: cross-engine resume parity
# ---------------------------------------------------------------------------

def test_engine_resume_request_is_token_identical(llama, engine_factory,
                                                  request_factory, sampling):
    """``resume_request`` re-ingests prompt + already-generated tokens via
    the restore path and continues sampling at the interruption point: the
    stitched output must equal an uninterrupted run token for token, under
    greedy AND seeded top-p."""
    import copy

    cfg, model, params = llama
    (req,) = request_factory(cfg.vocab_size, n=1, plen=20, max_tokens=24,
                             **sampling)
    ref_eng = engine_factory(model, params)
    ref_eng.add_request(copy.deepcopy(req))
    (ref,) = ref_eng.run_to_completion()
    assert len(ref.output_tokens) == 24

    for k in (1, 7, 23):
        eng = engine_factory(model, params)
        frames = []
        eng.resume_request(copy.deepcopy(req), ref.output_tokens[:k],
                           on_delta=frames.append)
        (out,) = eng.run_to_completion()
        assert out.output_tokens == ref.output_tokens
        assert eng.stats["resumed_tokens"] == k
        assert eng.stats["restores"] == 1
        # stream frames continue at offset k, contiguously
        offs = [f.offset for f in frames]
        toks = [t for f in frames for t in (f.tokens or [])]
        assert offs[0] == k and toks == ref.output_tokens[k:]
        assert all(f.offset + f.n_tokens == n.offset
                   for f, n in zip(frames, frames[1:]))


# ---------------------------------------------------------------------------
# property: random chaos schedules conserve requests (satellite 4)
# ---------------------------------------------------------------------------

def _check_chaos_conservation(seed, n_requests):
    """Under a random seeded chaos schedule (crashes, silent crashes,
    heartbeat loss, latency, node/instance/rack faults), every admitted
    request resolves EXACTLY once — a completion with consistent token
    accounting or a /v1 taxonomy error — and breakers never wedge open
    once healthy capacity is back."""
    sysd = _resilient(retry=RetryPolicy(max_attempts=3, attempt_timeout=300.0,
                                        stall_timeout=15.0))
    warm_up(sysd, MODEL)
    _hot(sysd, "polaris-ep")
    sysd.faults.rng.seed(seed)
    plan = sysd.faults.plan_chaos(
        sysd.endpoints, sysd.schedulers, horizon=240.0, start=5.0,
        crash_rate=1 / 80.0, silent_crash_rate=1 / 160.0,
        hb_loss_rate=1 / 120.0, latency_rate=1 / 120.0,
        instance_rate=1 / 80.0, node_rate=1 / 160.0, rack_rate=1 / 300.0,
        mean_outage=30.0)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    t0 = sysd.loop.now()
    futs, asms = {}, {}
    for i in range(n_requests):
        streamed = i % 2 == 0

        def _go(i=i, streamed=streamed):
            rid = f"p{i}"
            if streamed:
                futs[rid], asms[rid] = client.stream(
                    model=MODEL, prompt_tokens=32, max_tokens=40,
                    request_id=rid)
            else:
                futs[rid] = client.chat(model=MODEL, prompt_tokens=32,
                                        max_tokens=40, request_id=rid)

        sysd.loop.call_at(t0 + 5.0 + i * 20.0, _go)
    sysd.loop.run_until_idle()

    assert len(futs) == n_requests
    for rid, fut in futs.items():
        assert fut.done(), f"{rid} never resolved"
        if fut.error is not None:
            assert isinstance(fut.error, errors.APIError), \
                f"{rid} failed outside the taxonomy: {fut.error!r}"
        else:
            resp = fut.result()
            assert resp.usage.completion_tokens == 40
            if rid in asms:
                # no duplicated or lost stream positions
                assert asms[rid].n_tokens == 40
        # exactly-once in the activity log too
        recs = [r for r in sysd.metrics.records if r.request_id == rid]
        assert len(recs) == 1

    # every fault in the plan had a finite duration: after the horizon the
    # federation heals, and no breaker may wedge open against it
    sysd.loop.run_until(sysd.loop.now() + 120.0)
    probe = client.chat(model=MODEL, prompt_tokens=8, max_tokens=2)
    sysd.loop.run_until_idle()
    assert probe.error is None, \
        f"healthy federation rejected the probe after {len(plan)} faults"


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=5, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(seed=st.integers(0, 2**16), n_requests=st.integers(4, 10))
    def test_chaos_schedule_conserves_every_request(seed, n_requests):
        _check_chaos_conservation(seed, n_requests)

except ImportError:
    # no hypothesis in this environment: same property, fixed seeds
    @pytest.mark.parametrize("seed", [7, 1234, 99991])
    def test_chaos_schedule_conserves_every_request(seed):
        _check_chaos_conservation(seed, n_requests=6)
