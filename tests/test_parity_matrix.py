"""Cross-backend parity matrix: dense vs paged x greedy vs seeded top-p x
MHA vs GQA x speculative on/off.

One reference stream per (model, sampling) cell — the dense backend's
legacy host-driven path — and every other combination must reproduce it
token-for-token: the cache layout, the fused device loop, and the
draft-and-verify round are all optimizations of the SAME sampler, never
samplers of their own. Fused/speculative runs must also complete without a
single device->host logits transfer (the PR 2 ``TRANSFER_STATS`` hook).
"""
import pytest

from repro.serving import backends

KW = dict(max_slots=3, max_seq_len=64, page_size=16)
_REF = {}        # (arch, sampling) -> legacy dense reference stream


@pytest.mark.parametrize("spec", [0, 3], ids=["spec-off", "spec-on"])
def test_backend_sampling_grouping_spec_matrix(grouped_lm, sampling, spec,
                                               backend, engine_factory,
                                               request_factory, run_engine):
    cfg, model, params = grouped_lm
    kw = dict(KW)
    reqs = request_factory(cfg.vocab_size, n=3, plen=12, max_tokens=10,
                           **sampling)

    # reference: dense backend, legacy host-driven decode (no fusion) —
    # computed once per (model, sampling) cell and shared across the
    # backend/spec axes
    ref_key = (cfg.name, tuple(sorted(sampling.items())))
    if ref_key not in _REF:
        ref_eng = engine_factory(model, params, backend="slots",
                                 fused_decode=False, **kw)
        _REF[ref_key], _ = run_engine(ref_eng, reqs)
    ref = _REF[ref_key]

    backends.reset_transfer_stats()
    eng = engine_factory(
        model, params, backend=backend, spec_tokens=spec,
        draft=(model, params) if spec else None,
        decode_steps_per_sync=1 if spec else 4, **kw)
    got, eng = run_engine(eng, reqs)
    assert got == ref, (
        f"{backend} spec={spec} diverged from the dense legacy reference")
    # the device-resident paths never ship logits to the host
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    assert backends.TRANSFER_STATS["decode_logits_bytes"] == 0
    if spec:
        assert eng.stats["spec_rounds"] > 0
        assert eng.spec_acceptance_rate() > 0.5   # draft == target
