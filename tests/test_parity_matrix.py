"""Cross-backend parity matrix: dense vs paged x greedy vs seeded top-p x
MHA vs GQA x speculative on/off x single-device vs tensor-parallel mesh x
kernel path on/off.

One reference stream per (model, sampling) cell — the dense backend's
legacy host-driven path on a single device — and every other combination
must reproduce it token-for-token: the cache layout, the fused device
loop, the draft-and-verify round, the 4-way sharded execution, AND the
``use_kernel`` hot path are all optimizations of the SAME sampler, never
samplers of their own. Sharded logits differ from single-device by ~1e-6
(all-reduce accumulation order) and the kernel path's split context+tail
softmax reorders reductions similarly, but sampling is replicated over
full logits, so the argmax / seeded top-p decision — and therefore the
token stream — is identical. Fused/speculative runs must also complete
without a single device->host logits transfer (the PR 2
``TRANSFER_STATS`` hook), sharded or not.

The ``use_kernel`` axis here exercises the engine-level dispatch end to
end (on CPU that is the XLA twin of the fused kernel — same split
attention, view caching, and deferred page commit); numerical parity of
the actual Pallas kernels versus their jnp oracles is enforced at op
level in ``test_kernels.py`` interpret-mode tests, which is where the
kernel bodies run on non-TPU hosts without paying interpreter cost inside
a whole engine loop.
"""
import pytest

from repro.serving import backends

KW = dict(max_slots=3, max_seq_len=64, page_size=16)
_REF = {}        # (arch, sampling) -> legacy dense reference stream


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["xla-ops", "kernel"])
@pytest.mark.parametrize("spec", [0, 3], ids=["spec-off", "spec-on"])
def test_backend_sampling_grouping_spec_matrix(grouped_lm, sampling, spec,
                                               backend, mesh, use_kernel,
                                               engine_factory,
                                               request_factory, run_engine):
    cfg, model, params = grouped_lm
    if use_kernel and backend != "paged":
        pytest.skip("the kernel path is a paged-backend optimization")
    kw = dict(KW)
    reqs = request_factory(cfg.vocab_size, n=3, plen=12, max_tokens=10,
                           **sampling)

    # reference: dense backend, legacy host-driven decode (no fusion),
    # single device — computed once per (model, sampling) cell and shared
    # across the backend/spec/mesh axes
    ref_key = (cfg.name, tuple(sorted(sampling.items())))
    if ref_key not in _REF:
        ref_eng = engine_factory(model, params, backend="slots",
                                 fused_decode=False, **kw)
        _REF[ref_key], _ = run_engine(ref_eng, reqs)
    ref = _REF[ref_key]

    backends.reset_transfer_stats()
    eng = engine_factory(
        model, params, backend=backend, spec_tokens=spec,
        draft=(model, params) if spec else None, mesh=mesh,
        use_kernel=use_kernel,
        decode_steps_per_sync=1 if spec else 4, **kw)
    got, eng = run_engine(eng, reqs)
    tp = "1dev" if mesh is None else f"tp{mesh.shape['model']}"
    assert got == ref, (
        f"{backend} spec={spec} {tp} use_kernel={use_kernel} diverged "
        f"from the dense legacy single-device reference")
    # the device-resident paths never ship logits to the host — sampling
    # stays replicated on the mesh, so sharding must not break this
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    assert backends.TRANSFER_STATS["decode_logits_bytes"] == 0
    if spec:
        assert eng.stats["spec_rounds"] > 0
        assert eng.spec_acceptance_rate() > 0.5   # draft == target
