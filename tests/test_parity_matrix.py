"""Cross-backend parity matrix: dense vs paged x greedy vs seeded top-p x
MHA vs GQA x speculative on/off x single-device vs tensor-parallel mesh.

One reference stream per (model, sampling) cell — the dense backend's
legacy host-driven path on a single device — and every other combination
must reproduce it token-for-token: the cache layout, the fused device
loop, the draft-and-verify round, AND the 4-way sharded execution are all
optimizations of the SAME sampler, never samplers of their own. Sharded
logits differ from single-device by ~1e-6 (all-reduce accumulation
order), but sampling is replicated over full logits, so the argmax /
seeded top-p decision — and therefore the token stream — is identical.
Fused/speculative runs must also complete without a single device->host
logits transfer (the PR 2 ``TRANSFER_STATS`` hook), sharded or not.
"""
import pytest

from repro.serving import backends

KW = dict(max_slots=3, max_seq_len=64, page_size=16)
_REF = {}        # (arch, sampling) -> legacy dense reference stream


@pytest.mark.parametrize("spec", [0, 3], ids=["spec-off", "spec-on"])
def test_backend_sampling_grouping_spec_matrix(grouped_lm, sampling, spec,
                                               backend, mesh, engine_factory,
                                               request_factory, run_engine):
    cfg, model, params = grouped_lm
    kw = dict(KW)
    reqs = request_factory(cfg.vocab_size, n=3, plen=12, max_tokens=10,
                           **sampling)

    # reference: dense backend, legacy host-driven decode (no fusion),
    # single device — computed once per (model, sampling) cell and shared
    # across the backend/spec/mesh axes
    ref_key = (cfg.name, tuple(sorted(sampling.items())))
    if ref_key not in _REF:
        ref_eng = engine_factory(model, params, backend="slots",
                                 fused_decode=False, **kw)
        _REF[ref_key], _ = run_engine(ref_eng, reqs)
    ref = _REF[ref_key]

    backends.reset_transfer_stats()
    eng = engine_factory(
        model, params, backend=backend, spec_tokens=spec,
        draft=(model, params) if spec else None, mesh=mesh,
        decode_steps_per_sync=1 if spec else 4, **kw)
    got, eng = run_engine(eng, reqs)
    tp = "1dev" if mesh is None else f"tp{mesh.shape['model']}"
    assert got == ref, (
        f"{backend} spec={spec} {tp} diverged from the dense legacy "
        f"single-device reference")
    # the device-resident paths never ship logits to the host — sampling
    # stays replicated on the mesh, so sharding must not break this
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    assert backends.TRANSFER_STATS["decode_logits_bytes"] == 0
    if spec:
        assert eng.stats["spec_rounds"] > 0
        assert eng.spec_acceptance_rate() > 0.5   # draft == target
