"""Shared test fixtures: tiny-model / engine / request builders.

The serving test modules (prefix cache, decode fast path, speculative
decoding, cross-backend parity) all drive the same tiny reduced models
through the same engine entry points; the builders live here ONCE,
parameterized by backend (slots | paged), attention grouping (GQA | MHA)
and sampling mode (greedy | seeded top-p).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# The tensor-parallel axis of the test matrix runs on a simulated 4-device
# mesh; fake the devices on CPU up front (the flag is only read when jax
# initializes its backend, so it must be set before the import below).
_xla = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _xla:
    os.environ["XLA_FLAGS"] = \
        (_xla + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import copy  # noqa: E402
import dataclasses  # noqa: E402

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# reduced() keeps each source arch's head grouping: llama3.2-3b reduces to
# 4 query / 2 kv heads (GQA), qwen1.5-4b to 4 / 4 (MHA)
GQA_ARCH = "llama3.2-3b"
MHA_ARCH = "qwen1.5-4b"
SSM_ARCH = "mamba2-130m"


@pytest.fixture(scope="session")
def lm_factory():
    """Session-cached tiny-model builder:
    ``lm_factory(arch, seed=0, **cfg_overrides) -> (cfg, model, params)``.
    Params for a given (arch, seed, overrides) are built once per test
    session, so every module shares the same tiny models."""
    from repro.configs import REGISTRY, reduced
    from repro.models import make_model

    cache = {}

    def build(arch=GQA_ARCH, *, seed=0, **overrides):
        key = (arch, seed, tuple(sorted(overrides.items())))
        if key not in cache:
            cfg = reduced(REGISTRY[arch])
            if overrides:
                cfg = dataclasses.replace(cfg, **overrides)
            model = make_model(cfg)
            cache[key] = (cfg, model,
                          model.init_params(jax.random.PRNGKey(seed)))
        return cache[key]

    return build


@pytest.fixture(scope="session")
def llama(lm_factory):
    """Reduced llama3.2-3b (attention family, GQA): (cfg, model, params)."""
    return lm_factory(GQA_ARCH)


@pytest.fixture(scope="session")
def qwen(lm_factory):
    """Reduced qwen1.5-4b (attention family, MHA): (cfg, model, params)."""
    return lm_factory(MHA_ARCH)


@pytest.fixture(scope="session")
def mamba(lm_factory):
    """Reduced mamba2-130m (SSM family): (cfg, model, params)."""
    return lm_factory(SSM_ARCH)


# -- axis fixtures (parameterize a test by requesting them) -------------------

@pytest.fixture(params=["slots", "paged"])
def backend(request):
    """Engine cache backend under test."""
    return request.param


@pytest.fixture(params=["gqa", "mha"])
def grouped_lm(request, lm_factory):
    """Attention grouping axis: a GQA and an MHA tiny model."""
    return lm_factory(GQA_ARCH if request.param == "gqa" else MHA_ARCH)


@pytest.fixture(params=["greedy", "topp"])
def sampling(request):
    """Sampling-mode axis as SamplingParams kwargs."""
    return dict(temperature=0.0) if request.param == "greedy" \
        else dict(temperature=0.8, top_p=0.9)


@pytest.fixture(params=["1dev", "tp4"], scope="session")
def mesh(request):
    """Tensor-parallel axis: None (legacy single-device layout) vs a
    simulated 1x4 (data, model) mesh — params TP-sharded, KV sharded with
    the heads, sampling replicated. Session-scoped: the Mesh object is
    immutable and shared by every sharded cell."""
    if request.param == "1dev":
        return None
    if jax.device_count() < 4:
        pytest.skip("tensor-parallel cells need >= 4 devices; run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 4)


# -- builder fixtures ---------------------------------------------------------

@pytest.fixture
def engine_factory():
    """``engine_factory(model, params, draft=(dm, dp), **cfg_overrides)``
    -> ContinuousBatchingEngine (paged, 4 slots, page 16 by default)."""
    from repro.serving.engine import ContinuousBatchingEngine, EngineConfig

    def build(model, params, *, draft=None, **overrides):
        kw = dict(max_slots=4, max_seq_len=128, backend="paged",
                  page_size=16)
        kw.update(overrides)
        dm, dp = draft if draft is not None else (None, None)
        return ContinuousBatchingEngine(model, params, EngineConfig(**kw),
                                        draft_model=dm, draft_params=dp)

    return build


@pytest.fixture
def request_factory():
    """``request_factory(vocab, n=5, ...)`` -> list[InferenceRequest] with
    ramped prompt lengths / token budgets (the decode-path workload), or
    fixed prompts via ``prompts=[...]``."""
    from repro.serving.request import InferenceRequest, SamplingParams

    def build(vocab, n=5, plen=18, max_tokens=22, temperature=0.0,
              top_p=1.0, stop=None, seed0=0, rng_seed=7, prompts=None,
              ramp=True):
        rng = np.random.default_rng(rng_seed)
        out = []
        if prompts is not None:
            for i, p in enumerate(prompts):
                out.append(InferenceRequest(
                    model="m", prompt_tokens=list(p), request_id=f"r{i}",
                    sampling=SamplingParams(
                        max_tokens=max_tokens, temperature=temperature,
                        top_p=top_p, seed=seed0 + i, stop_token=stop)))
            return out
        for i in range(n):
            out.append(InferenceRequest(
                model="m",
                prompt_tokens=rng.integers(
                    2, vocab, size=plen + (i if ramp else 0)).tolist(),
                request_id=f"r{i}",
                sampling=SamplingParams(
                    max_tokens=max_tokens + (i if ramp else 0),
                    temperature=temperature, top_p=top_p, seed=seed0 + i,
                    stop_token=stop)))
        return out

    return build


@pytest.fixture
def run_engine():
    """Feed deep-copied requests, run to completion, return
    ``({request_id: (tokens, finish_reason)}, engine)``."""

    def run(eng, reqs, *, expect_all=True):
        for r in copy.deepcopy(reqs):
            eng.add_request(r)
        outs = eng.run_to_completion()
        if expect_all:
            assert len(outs) == len(reqs)
        return {o.request_id: (o.output_tokens, o.finish_reason)
                for o in outs}, eng

    return run


@pytest.fixture
def shared_prefix_prompts():
    """Prompt lists sharing a page-aligned leading block (prefix-cache
    workload)."""

    def build(vocab, n, n_shared=40, n_tail=24, seed=0):
        rng = np.random.default_rng(seed)
        shared = rng.integers(2, vocab, size=n_shared).tolist()
        return [shared + rng.integers(2, vocab, size=n_tail).tolist()
                for _ in range(n)]

    return build
