import os

# Tests run single-device; ONLY launch/dryrun.py sets the 512-device flag.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
