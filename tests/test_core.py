"""Control-plane behaviour tests: auth, scheduler, federation (§4.5),
gateway optimizations (§5.3.1), auto-scaling (Fig. 4), hot nodes, batch mode
(§4.4), and fault tolerance."""
import math

import pytest

from repro.core import (AccessPolicy, AuthError, AuthService,
                        CachingAuthClient, ClusterScheduler, EventLoop,
                        GatewayConfig, JobState)
from repro.core.testbed import (LLAMA8B, LLAMA70B, System, build_system,
                                default_deployment, drive_workload, warm_up)
from repro.data.workload import make_workload


def _mk(deps=None, **kw):
    return build_system(deps, **kw)


# ---------------------------------------------------------------------------
# auth
# ---------------------------------------------------------------------------

def test_auth_token_lifecycle_and_cache():
    loop = EventLoop()
    svc = AuthService(loop, introspection_latency=2.0)
    svc.add_user("alice", groups=("users",))
    tok = svc.issue_token("alice")
    client = CachingAuthClient(loop, svc)
    out = []
    client.validate(tok, out.append)
    loop.run_until_idle()
    assert out[0].user == "alice"
    assert loop.now() == pytest.approx(2.0)   # one introspection round trip
    # cached second call: no added introspection
    client.validate(tok, out.append)
    loop.run_until_idle()
    assert svc.introspections == 1 and client.hits == 1

    bad = []
    client.validate("bogus", bad.append)
    loop.run_until_idle()
    assert isinstance(bad[0], AuthError)


def test_auth_coalesces_concurrent_bursts():
    loop = EventLoop()
    svc = AuthService(loop, introspection_latency=2.0, rate_limit_per_s=10)
    svc.add_user("alice")
    tok = svc.issue_token("alice")
    client = CachingAuthClient(loop, svc)
    out = []
    for _ in range(500):                      # burst far above provider limit
        client.validate(tok, out.append)
    loop.run_until_idle()
    assert svc.introspections == 1            # Optimization 2
    assert all(getattr(o, "user", None) == "alice" for o in out)


def test_rbac_policy():
    pol = AccessPolicy(model_groups={"secret-model": "insiders"})
    from repro.core.auth import Identity
    assert not pol.allowed(Identity("bob", ("users",)), "secret-model")
    assert pol.allowed(Identity("eve", ("insiders",)), "secret-model")
    assert pol.allowed(Identity("bob", ("users",)), "open-model")


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_queue_and_release():
    loop = EventLoop()
    sched = ClusterScheduler(loop, "c", num_nodes=2, startup_delay=10.0)
    started = []
    j1 = sched.submit(2, on_start=lambda j: started.append(j.job_id))
    j2 = sched.submit(1, on_start=lambda j: started.append(j.job_id))
    loop.run_until_idle()
    assert started == [j1.job_id]             # j2 waits: no free nodes
    assert j2.state == JobState.QUEUED
    sched.release(j1)
    loop.run_until_idle()
    assert started == [j1.job_id, j2.job_id]
    assert j2.queue_wait > 0


def test_scheduler_node_failure_kills_job():
    loop = EventLoop()
    sched = ClusterScheduler(loop, "c", num_nodes=2, startup_delay=1.0)
    ended = []
    j = sched.submit(2, on_start=lambda j: None,
                     on_end=lambda j: ended.append(j.state))
    loop.run_until_idle()
    victim = j.nodes[0]
    sched.fail_node(victim)
    assert ended == [JobState.FAILED]
    assert sched.available_nodes() == 1       # one node down, one returned
    sched.restore_node(victim)
    assert sched.available_nodes() == 2


# ---------------------------------------------------------------------------
# federation (§4.5 priority rules)
# ---------------------------------------------------------------------------

def _two_cluster_system(nodes_a=4, nodes_b=4):
    deps = {
        "sophia": {LLAMA70B.name: default_deployment(LLAMA70B)},
        "polaris": {LLAMA70B.name: default_deployment(LLAMA70B)},
    }
    return build_system(deps, nodes_per_cluster=nodes_a)


def test_federation_prefers_active_instance_then_free_nodes():
    sysd = _two_cluster_system()
    model = LLAMA70B.name
    # cold: no active instances anywhere -> rule 2 picks first with free nodes
    ep = sysd.router.select_endpoint(model)
    assert ep == "sophia-ep"
    assert sysd.router.decisions[-1][2] == "free-nodes"
    # warm polaris: rule 1 must now pick polaris despite registry order
    sysd.endpoints["polaris-ep"]._spawn_instance(model)
    sysd.loop.run_until_idle()
    ep = sysd.router.select_endpoint(model)
    assert ep == "polaris-ep"
    assert sysd.router.decisions[-1][2] == "active-instance"


def test_federation_falls_back_to_configured_order():
    sysd = _two_cluster_system()
    model = LLAMA70B.name
    for s in sysd.schedulers.values():        # exhaust all nodes
        while s.available_nodes():
            s.submit(1, on_start=lambda j: None)
    sysd.loop.run_until_idle()
    ep = sysd.router.select_endpoint(model)
    assert ep == "sophia-ep"
    assert sysd.router.decisions[-1][2] == "configured-order"


def test_federation_skips_unhealthy_endpoint():
    sysd = _two_cluster_system()
    sysd.health.mark_down("sophia-ep")
    sysd.loop.run_until(20.0)                 # health monitor tick
    assert sysd.router.select_endpoint(LLAMA70B.name) == "polaris-ep"


# ---------------------------------------------------------------------------
# gateway behaviour + the three paper optimizations
# ---------------------------------------------------------------------------

def test_gateway_validates_and_rate_limits():
    sysd = _mk(gateway_config=GatewayConfig(rate_limit_per_user=1.0,
                                            rate_burst=2.0))
    warm_up(sysd, LLAMA70B.name)
    tok = sysd.token_for("alice")
    futs = [sysd.gateway.submit(tok, {"model": LLAMA70B.name,
                                      "prompt_tokens": 8, "max_tokens": 2})
            for _ in range(5)]
    bad = sysd.gateway.submit(tok, {"model": LLAMA70B.name,
                                    "prompt_tokens": -1, "max_tokens": 0})
    sysd.loop.run_until_idle()
    errs = [f for f in futs if f.error is not None]
    assert len(errs) == 3                     # burst of 2 + 1 regenerated token
    assert bad.error is not None              # invalid payload rejected


def test_gateway_response_cache():
    sysd = _mk()
    warm_up(sysd, LLAMA70B.name)
    tok = sysd.token_for("alice")
    req = {"model": LLAMA70B.name, "prompt_tokens": 64, "max_tokens": 16,
           "prompt_hash": "same-prompt", "temperature": 0.0}
    f1 = sysd.gateway.submit(tok, dict(req))
    sysd.loop.run_until_idle()
    t0 = sysd.loop.now()
    f2 = sysd.gateway.submit(tok, dict(req))
    sysd.loop.run_until_idle()
    assert f1.result()["output_tokens"] == 16
    assert f2.result()["output_tokens"] == 16
    assert sysd.gateway.cache.hits == 1
    assert sysd.loop.now() - t0 < 0.1         # served from cache, no backend


def test_optimizations_each_cut_latency():
    """Opt1 (futures vs polling), Opt2 (auth cache), Opt3 (async workers):
    each toggle must strictly improve median latency under load."""
    model = LLAMA70B.name
    medians = {}
    variants = {
        "optimized": dict(gateway_config=GatewayConfig(), auth_cache=True),
        "polling": dict(gateway_config=GatewayConfig(poll_interval=2.0),
                        auth_cache=True),
        "no_auth_cache": dict(gateway_config=GatewayConfig(),
                              auth_cache=False, connection_cache=False),
        "sync_workers": dict(gateway_config=GatewayConfig(
            workers=9, blocking_workers=True), auth_cache=True),
    }
    for name, kw in variants.items():
        sysd = _mk(**kw)
        warm_up(sysd, model)
        wl = make_workload(60, rate=4.0, seed=7)
        s = drive_workload(sysd, wl, model)
        medians[name] = s["median_e2e_s"]
    assert medians["optimized"] < medians["polling"]
    assert medians["optimized"] < medians["no_auth_cache"]
    assert medians["optimized"] < medians["sync_workers"]


# ---------------------------------------------------------------------------
# auto-scaling + hot nodes
# ---------------------------------------------------------------------------

def test_autoscale_to_cap_and_throughput_gain():
    model = LLAMA70B.name

    def run(max_inst):
        # fast storage + short cooldown so scaling completes within the run
        deps = {"sophia": {model: default_deployment(
            LLAMA70B, max_instances=max_inst, storage_bw=40e9,
            scale_cooldown=8.0)}}
        sysd = _mk(deps, startup_delay=5.0)
        warm_up(sysd, model)
        wl = make_workload(1000, rate=float("inf"), seed=3)
        return sysd, drive_workload(sysd, wl, model)

    sys1, s1 = run(1)
    sys4, s4 = run(4)
    ep = sys4.endpoints["sophia-ep"]
    assert len(ep.instances[model]) == 4      # scaled to the cap
    assert s4["output_tok_per_s"] > 1.5 * s1["output_tok_per_s"]
    assert s4["median_e2e_s"] < s1["median_e2e_s"]


def test_hot_node_idle_release():
    model = LLAMA70B.name
    deps = {"sophia": {model: default_deployment(LLAMA70B,
                                                 idle_timeout=100.0)}}
    sysd = _mk(deps)
    warm_up(sysd, model)
    ep = sysd.endpoints["sophia-ep"]
    assert ep.model_states(model) == ["running"]
    # second request while hot: no new job, reuses the instance
    tok = sysd.token_for("alice")
    f = sysd.gateway.submit(tok, {"model": model, "prompt_tokens": 16,
                                  "max_tokens": 4})
    sysd.loop.run_until_idle()
    assert f.error is None
    assert len(sysd.schedulers["sophia"].jobs) == 1
    # idle past the timeout -> released, nodes returned
    sysd.loop.run_until(sysd.loop.now() + 200.0)
    assert ep.model_states(model) == []
    assert sysd.schedulers["sophia"].available_nodes() == 24


def test_cold_start_pipeline_states():
    sysd = _mk()
    model = LLAMA70B.name
    tok = sysd.token_for("alice")
    f = sysd.gateway.submit(tok, {"model": model, "prompt_tokens": 16,
                                  "max_tokens": 4})
    sysd.loop.run_until(25.0)                 # past startup, still loading
    states = sysd.gateway.jobs_status()[model]
    assert states[0]["state"] in ("queued", "starting")
    sysd.loop.run_until_idle()
    assert f.error is None
    assert f.result()["output_tokens"] == 4


# ---------------------------------------------------------------------------
# batch mode (§4.4)
# ---------------------------------------------------------------------------

def test_batch_mode_dedicated_job_and_throughput():
    sysd = _mk()
    model = LLAMA70B.name
    reqs = [{"request_id": f"b{i}", "prompt_tokens": 128, "max_tokens": 128}
            for i in range(500)]
    job = sysd.batch.submit_batch(model, reqs)
    sysd.loop.run_until_idle()
    st = sysd.batch.status(job.batch_id)
    assert st["state"] == "completed"
    assert st["completed"] == 500
    assert st["output_tokens"] == 500 * 128
    # dedicated instance released its job at completion
    assert sysd.schedulers["sophia"].available_nodes() == 24
    # amortized throughput beats the online engine's per-request path
    dur = job.finish_time - job.submit_time
    assert st["output_tokens"] / dur > 500


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_instance_failure_requeues_inflight():
    sysd = _mk()
    model = LLAMA70B.name
    warm_up(sysd, model)
    wl = make_workload(40, rate=float("inf"), seed=5)
    ep = sysd.endpoints["sophia-ep"]
    # fail mid-generation: shortly after prompts land on the engine
    sysd.faults.fail_instance_at(ep, model, t=sysd.loop.now() + 3.0)
    s = drive_workload(sysd, wl, model)
    assert s["errors"] == 0                   # every request still completed
    assert s["completed"] == 40
    assert ep.stats["restarts"] == 1
    assert ep.stats["requeued"] > 0


def test_node_failure_recovers_via_new_job():
    sysd = _mk()
    model = LLAMA70B.name
    warm_up(sysd, model)
    sched = sysd.schedulers["sophia"]
    job = next(j for j in sched.jobs.values()
               if j.state == JobState.RUNNING)
    wl = make_workload(30, rate=float("inf"), seed=6)
    sysd.faults.fail_node_at(sched, job.nodes[0], t=sysd.loop.now() + 20.0,
                             restore_after=300.0)
    s = drive_workload(sysd, wl, model)
    assert s["errors"] == 0 and s["completed"] == 30


def test_endpoint_outage_fails_over_to_federated_cluster():
    deps = {
        "sophia": {LLAMA70B.name: default_deployment(LLAMA70B)},
        "polaris": {LLAMA70B.name: default_deployment(LLAMA70B)},
    }
    sysd = _mk(deps)
    warm_up(sysd, LLAMA70B.name)              # warm on sophia
    sysd.health.mark_down("sophia-ep")
    sysd.loop.run_until(sysd.loop.now() + 20.0)
    ep = sysd.router.select_endpoint(LLAMA70B.name)
    assert ep == "polaris-ep"
    tok = sysd.token_for("alice")
    f = sysd.gateway.submit(tok, {"model": LLAMA70B.name,
                                  "prompt_tokens": 16, "max_tokens": 4})
    sysd.loop.run_until_idle()
    assert f.error is None
    assert f.result()["endpoint"] == "polaris-ep"


def test_hedged_request_beats_straggler():
    """Straggler mitigation (DESIGN §8): a request stuck behind a saturated
    instance is hedged to the other cluster after ``hedge_after`` seconds;
    first completion wins and the duplicate is ignored."""
    from repro.core.instances import SimRequest

    def run(hedge_after):
        deps = {
            "sophia": {LLAMA70B.name: default_deployment(LLAMA70B)},
            "polaris": {LLAMA70B.name: default_deployment(LLAMA70B)},
        }
        sysd = _mk(deps, gateway_config=GatewayConfig(
            hedge_after=hedge_after))
        warm_up(sysd, LLAMA70B.name)                  # sophia hot
        # bring polaris hot too (otherwise the hedge pays a cold start)
        pol = sysd.endpoints["polaris-ep"]
        pol._spawn_instance(LLAMA70B.name)
        sysd.loop.run_until(sysd.loop.now() + 120.0)
        # saturate sophia's engine with a long backlog
        soph = sysd.endpoints["sophia-ep"].instances[LLAMA70B.name][0]
        for i in range(600):
            soph.submit(SimRequest(f"bg{i}", 256, 256), None, lambda r: None)
        t0 = sysd.loop.now()
        hedges0 = sysd.gateway.hedges       # warm-up cold start may hedge too
        done_at = {}
        fut = sysd.gateway.submit(sysd.token_for("u"), {
            "model": LLAMA70B.name, "prompt_tokens": 64, "max_tokens": 32})
        fut.add_done_callback(
            lambda f: done_at.__setitem__("t", sysd.loop.now()))
        sysd.loop.run_until_idle()          # also drains the backlog
        assert fut.error is None
        return sysd, done_at["t"] - t0, fut.result(), \
            sysd.gateway.hedges - hedges0

    sys_h, t_hedged, res, n_hedges = run(hedge_after=10.0)
    assert n_hedges == 1
    assert res["endpoint"] == "polaris-ep"            # the hedge won
    sys_n, t_plain, _, n0 = run(hedge_after=None)
    assert n0 == 0
    assert t_hedged < t_plain / 2                     # it actually helped
