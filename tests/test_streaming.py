"""End-to-end token streaming: real-engine parity (streamed frames
re-assembled must be token-identical to the non-streamed run for greedy,
seeded top-p, and spec-decode on both backends), abort-mid-stream KV
reclaim, and the DES gateway channel (TTFT/ITL at the gateway, client
disconnect propagation, hedge first-token-wins cancellation, rate-limit
retry-after, and the response-cache content-hash regression)."""
import copy


from repro.api import StreamAssembler, errors, schemas
from repro.api.client import FirstClient
from repro.core.gateway import GatewayConfig
from repro.core.testbed import (LLAMA70B, build_system, default_deployment,
                                warm_up)


# ---------------------------------------------------------------------------
# real engine: streamed == non-streamed (backend x sampling x spec matrix)
# ---------------------------------------------------------------------------

def _run_streamed(eng, reqs):
    asms = {}
    for r in copy.deepcopy(reqs):
        asm = StreamAssembler()
        asms[r.request_id] = asm
        eng.add_request(r, on_delta=asm)
    outs = eng.run_to_completion()
    return {o.request_id: o for o in outs}, asms


def test_stream_parity_matrix(backend, grouped_lm, sampling, engine_factory,
                              request_factory, run_engine):
    """Streamed deltas reassemble to the exact non-streamed token stream
    on slots/paged x GQA/MHA x greedy/top-p."""
    cfg, model, params = grouped_lm
    reqs = request_factory(cfg.vocab_size, n=4, **sampling)
    ref, _ = run_engine(engine_factory(model, params, backend=backend),
                        reqs)
    outs, asms = _run_streamed(engine_factory(model, params,
                                              backend=backend), reqs)
    assert len(outs) == len(reqs)
    for rid, out in outs.items():
        asm = asms[rid]
        assert asm.finished and asm.finish_reason == out.finish_reason
        assert asm.tokens == out.output_tokens       # token-identical
        assert asm.tokens == ref[rid][0]             # == non-streamed run
        assert asm.n_tokens == out.num_output_tokens


def test_stream_parity_spec_decode(llama, lm_factory, engine_factory,
                                   request_factory, sampling):
    """Speculative decoding emits per-round bursts; the reassembled stream
    must still equal the non-speculative reference."""
    cfg, model, params = llama
    _, dmodel, dparams = lm_factory("llama3.2-3b", seed=3, num_layers=1)
    reqs = request_factory(cfg.vocab_size, n=3, **sampling)

    def build():
        return engine_factory(model, params, draft=(dmodel, dparams),
                              spec_tokens=3)

    plain = engine_factory(model, params)
    for r in copy.deepcopy(reqs):
        plain.add_request(r)
    ref = {o.request_id: o.output_tokens
           for o in plain.run_to_completion()}
    outs, asms = _run_streamed(build(), reqs)
    for rid, out in outs.items():
        assert asms[rid].tokens == out.output_tokens == ref[rid]
        assert asms[rid].finished


def test_stream_fused_multistep_frames(llama, engine_factory,
                                       request_factory):
    """K>1 fused decode surfaces tokens in bursts: frames carry up to K
    tokens each and still reassemble exactly."""
    cfg, model, params = llama
    reqs = request_factory(cfg.vocab_size, n=3, max_tokens=18)
    ref_outs, _ = _run_streamed(engine_factory(model, params), reqs)
    outs, asms = _run_streamed(
        engine_factory(model, params, decode_steps_per_sync=4), reqs)
    for rid, out in outs.items():
        assert asms[rid].tokens == out.output_tokens
        assert asms[rid].tokens == ref_outs[rid].output_tokens
        assert max(d.n_tokens for d in asms[rid].deltas) > 1


def test_abort_mid_stream_reclaims_pages(llama, engine_factory,
                                         request_factory):
    """Client disconnect mid-stream: abort() frees the sequence's KV pages
    and no further frames arrive."""
    cfg, model, params = llama
    eng = engine_factory(model, params, enable_prefix_cache=True)
    kv = eng.backend.kv
    reqs = request_factory(cfg.vocab_size, n=2, max_tokens=40)
    asms = {r.request_id: StreamAssembler() for r in reqs}
    for r in reqs:
        eng.add_request(r, on_delta=asms[r.request_id])
    # step until the victim has streamed a few frames
    while len(asms["r0"].deltas) < 3:
        eng.step()
    frames_at_abort = len(asms["r0"].deltas)
    assert eng.abort("r0")
    outs = eng.run_to_completion()
    assert {o.request_id for o in outs} == {"r1"}
    # no frame after the abort, and the stream never "finished"
    assert len(asms["r0"].deltas) == frames_at_abort
    assert not asms["r0"].finished
    # every page is reclaimable again (free_pages counts LRU-parked pages;
    # page 0 is the allocator's reserved null page)
    assert kv.free_pages == kv.num_pages - 1


# ---------------------------------------------------------------------------
# DES gateway: streaming channel, cancellation, hedging, admission control
# ---------------------------------------------------------------------------

def _system(**gw):
    deps = {"sophia": {LLAMA70B.name: default_deployment(LLAMA70B)},
            "polaris": {LLAMA70B.name: default_deployment(LLAMA70B)}}
    return build_system(deps, gateway_config=GatewayConfig(**gw))


def test_gateway_stream_observes_ttft_and_itl():
    sysd = _system()
    warm_up(sysd, LLAMA70B.name)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    fut, asm = client.stream(model=LLAMA70B.name, prompt_tokens=64,
                             max_tokens=24, request_id="s1")
    ref = client.chat(model=LLAMA70B.name, prompt_tokens=64, max_tokens=24)
    sysd.loop.run_until_idle()
    resp = fut.result()
    # streamed == non-streamed token accounting
    assert asm.n_tokens == resp.usage.completion_tokens == 24
    assert ref.result().usage.completion_tokens == 24
    assert asm.finished and asm.finish_reason == "length"
    # the client saw tokens strictly before completion
    assert asm.ttft < resp.finish_time + 1e-9
    assert len(asm.deltas) > 2
    # gateway-side record: streamed flag, frames, and inter-frame gaps
    rec = next(r for r in sysd.metrics.records if r.request_id == "s1")
    assert rec.streamed and rec.stream_frames >= 24
    assert rec.first_token > rec.arrival
    assert len(rec.itl) == rec.stream_frames - 1
    assert all(g >= 0 for g in rec.itl)
    s = sysd.metrics.summary()
    assert s["streamed"] == 1 and "stream_median_itl_s" in s


def test_gateway_cancel_propagates_to_engine():
    sysd = _system()
    warm_up(sysd, LLAMA70B.name)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    fut, asm = client.stream(model=LLAMA70B.name, prompt_tokens=64,
                             max_tokens=5000, request_id="c1")
    ep = sysd.endpoints["sophia-ep"]

    def disconnect():
        assert client.cancel("c1")

    sysd.loop.call_after(30.0, disconnect)
    sysd.loop.run_until_idle()
    assert isinstance(fut.error, errors.RequestCancelled)
    # the engine slot was freed: nothing is running or queued any more
    inst = ep.instances[LLAMA70B.name][0]
    assert inst.engine.load == 0
    assert inst.engine.total_aborted == 1
    assert ep.stats["aborted"] == 1
    # frames stopped, and the metrics record carries the taxonomy code
    rec = next(r for r in sysd.metrics.records if r.request_id == "c1")
    assert not rec.ok and rec.error_code == "request_cancelled"
    assert asm.n_tokens < 5000


def test_stream_survives_instance_failure_without_duplicates():
    """Fault-tolerance requeue restarts generation from token 0; the
    gateway dedupes re-emitted frames by stream offset, so the client
    still sees exactly ``max_tokens`` tokens, each once."""
    sysd = _system()
    warm_up(sysd, LLAMA70B.name)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    fut, asm = client.stream(model=LLAMA70B.name, prompt_tokens=64,
                             max_tokens=200, request_id="f1")
    ep = sysd.endpoints["sophia-ep"]
    # kill the serving instance mid-stream (the +4s offset clears alice's
    # first-token 2s auth introspection and lands mid-decode)
    sysd.faults.fail_instance_at(ep, LLAMA70B.name, t=sysd.loop.now() + 4.0)
    sysd.loop.run_until_idle()
    assert fut.error is None
    assert ep.stats["restarts"] == 1 and ep.stats["requeued"] >= 1
    assert asm.finished
    assert asm.n_tokens == fut.result().usage.completion_tokens == 200
    # the gateway metrics saw each token-bearing frame exactly once (DES
    # syncs are K=1 here: one token per frame; the finish frame carries
    # none and is not counted)
    rec = next(r for r in sysd.metrics.records if r.request_id == "f1")
    assert rec.streamed and rec.stream_frames == 200


def test_hedge_loser_is_cancelled_on_first_token():
    """The losing hedge endpoint must stop decoding (slot freed) instead
    of burning through max_tokens after the race is decided."""
    from repro.core.instances import SimRequest

    sysd = _system(hedge_after=10.0)
    warm_up(sysd, LLAMA70B.name)                  # sophia hot
    pol = sysd.endpoints["polaris-ep"]
    pol._spawn_instance(LLAMA70B.name)
    sysd.loop.run_until(sysd.loop.now() + 120.0)
    soph = sysd.endpoints["sophia-ep"].instances[LLAMA70B.name][0]
    for i in range(600):                          # saturate sophia
        soph.submit(SimRequest(f"bg{i}", 256, 256), None, lambda r: None)
    # the warm-up's cold start may itself have hedged: measure deltas
    hedges0 = sysd.gateway.hedges
    cancelled0 = sysd.metrics.hedges_cancelled
    aborted0 = sysd.endpoints["sophia-ep"].stats["aborted"]
    client = FirstClient(sysd.gateway, sysd.token_for("u"))
    fut = client.chat(model=LLAMA70B.name, prompt_tokens=64,
                      max_tokens=4000, request_id="h1")
    sysd.loop.run_until_idle()
    assert fut.error is None
    res = fut.result()
    assert res.endpoint_id == "polaris-ep"        # the hedge won
    assert sysd.gateway.hedges - hedges0 == 1
    assert sysd.metrics.hedges_cancelled - cancelled0 == 1
    # the loser (original dispatch on sophia) was aborted mid-flight
    assert sysd.endpoints["sophia-ep"].stats["aborted"] - aborted0 == 1
    assert soph.engine.total_aborted == 1
    st = sysd.gateway.jobs_status()["_gateway"]
    assert st["hedges_cancelled"] == sysd.metrics.hedges_cancelled


def test_rate_limit_error_carries_retry_after():
    sysd = _system(rate_limit_per_user=0.5, rate_burst=1.0)
    warm_up(sysd, LLAMA70B.name)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    futs = [client.chat(model=LLAMA70B.name, prompt_tokens=8, max_tokens=2)
            for _ in range(3)]
    sysd.loop.run_until_idle()
    errs = [f.error for f in futs if f.error is not None]
    assert errs and all(isinstance(e, errors.RateLimitError) for e in errs)
    # bucket refills at 0.5 tok/s -> next token within (0, 2] seconds
    assert all(0 < e.retry_after <= 2.0 for e in errs)
    assert all(e.to_dict()["error"]["code"] == "rate_limit_error"
               for e in errs)
    # surfaced in /jobs and the metrics log
    st = sysd.gateway.jobs_status()["_gateway"]
    assert st["rate_limited"] == len(errs)
    assert st["rejections"]["rate_limit_error"] == len(errs)
    assert sysd.metrics.rejections["rate_limit_error"] == len(errs)
    recs = [r for r in sysd.metrics.records
            if r.error_code == "rate_limit_error"]
    assert len(recs) == len(errs)


def test_unknown_model_and_queue_full_codes():
    sysd = _system(max_queue=2, workers=1, request_cpu_time=5.0)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    bad = client.chat(model="nonexistent-13b", prompt_tokens=8,
                      max_tokens=2)
    assert isinstance(bad.error, errors.ModelNotFoundError)
    futs = [client.chat(model=LLAMA70B.name, prompt_tokens=8, max_tokens=2)
            for _ in range(6)]
    overloaded = [f for f in futs
                  if isinstance(f.error, errors.OverloadedError)]
    assert overloaded                    # queue of 2 overflowed
    st = sysd.gateway.jobs_status()["_gateway"]
    assert st["rejected_queue_full"] == len(overloaded)
    assert st["rejections"]["overloaded"] == len(overloaded)
    assert st["rejections"]["model_not_found"] == 1
    sysd.loop.run_until_idle()


def test_response_cache_requires_content_identity():
    """Regression: two different prompts with equal token counts must NOT
    share a response-cache entry (the old key fell back to the count)."""
    sysd = _system()
    warm_up(sysd, LLAMA70B.name)
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    kw = dict(model=LLAMA70B.name, max_tokens=16, temperature=0.0)
    # count-only prompts: same count, no content identity -> no caching
    f1 = client.chat(prompt_tokens=64, **kw)
    sysd.loop.run_until_idle()
    f2 = client.chat(prompt_tokens=64, **kw)
    sysd.loop.run_until_idle()
    assert f1.error is None and f2.error is None
    assert sysd.gateway.cache.hits == 0
    # distinct token ids of EQUAL length hash apart -> both miss
    g1 = client.complete(prompt_tokens=[1, 2, 3, 4], **kw)
    sysd.loop.run_until_idle()
    g2 = client.complete(prompt_tokens=[9, 8, 7, 6], **kw)
    sysd.loop.run_until_idle()
    assert g1.error is None and g2.error is None
    assert sysd.gateway.cache.hits == 0
    # identical ids DO hit
    g3 = client.complete(prompt_tokens=[1, 2, 3, 4], **kw)
    sysd.loop.run_until_idle()
    assert g3.error is None and sysd.gateway.cache.hits == 1
    assert g3.result().cached


# ---------------------------------------------------------------------------
# /v1/batches surface
# ---------------------------------------------------------------------------

def test_v1_batches_status_and_per_request_results():
    sysd = _system()
    client = FirstClient(sysd.gateway, sysd.token_for("alice"))
    items = [schemas.BatchItem(
        custom_id=f"item-{i}",
        body=schemas.CompletionRequest(model=LLAMA70B.name,
                                       prompt_tokens=64, max_tokens=32))
        for i in range(5)]
    # two malformed items — one typed, one a raw NDJSON dict — become
    # per-request errors while the rest of the batch still completes
    items.append(schemas.BatchItem(
        custom_id="bad", body=schemas.CompletionRequest(
            model=LLAMA70B.name, prompt_tokens=-4, max_tokens=8)))
    items.append({"custom_id": "bad-dict", "url": "/v1/completions",
                  "body": {"model": LLAMA70B.name, "prompt_tokens": 8,
                           "max_tokens": 0}})
    fut = client.create_batch(items)
    sysd.loop.run_until_idle()
    st0 = fut.result()
    assert st0.total == 7
    final = client.batch_status(st0.id)
    assert final.status == "completed"
    assert final.completed == 5 and final.failed == 2
    assert final.output_tokens == 5 * 32
    results = {r["custom_id"]: r for r in client.batch_results(st0.id)}
    assert len(results) == 7
    for bad in ("bad", "bad-dict"):
        assert results[bad]["error"]["error"]["code"] == \
            "invalid_request_error"
    ok = results["item-0"]["response"]
    assert ok.usage.completion_tokens == 32
    assert ok.usage.total_tokens == 96
    # OpenAI batch object wire shape round-trips
    d = final.to_dict()
    assert d["request_counts"] == {"total": 7, "completed": 5, "failed": 2}
    assert schemas.BatchStatus.from_dict(d).to_dict() == d
