"""Prefix caching + chunked prefill: correctness locks.

The contract under test: turning on block-level KV reuse and/or chunked
prefill must never change WHAT the engine generates — only how much prefill
compute it spends and how it is scheduled. Greedy (temperature=0) outputs
are therefore compared token-for-token against the cold one-shot baseline.

Model/engine/request builders come from tests/conftest.py.
"""
import numpy as np
import pytest

from repro.serving.kv_cache import OutOfPages, PagedKVCache
from repro.serving.request import InferenceRequest, SamplingParams

PAGE = 16


@pytest.fixture
def run_prompts(engine_factory, request_factory, run_engine):
    """Greedy outputs for a list of prompts: (outputs dict, engine)."""

    def _run(model, params, prompts, max_tokens=8, **cfg_kw):
        eng = engine_factory(model, params, **cfg_kw)
        reqs = request_factory(0, prompts=prompts, max_tokens=max_tokens,
                               seed0=0)
        outs, eng = run_engine(eng, reqs)
        return {rid: toks for rid, (toks, _) in outs.items()}, eng

    return _run


# ---------------------------------------------------------------------------
# host-side allocator unit behaviour
# ---------------------------------------------------------------------------

def test_prefix_hit_shares_pages_and_refcounts():
    kv = PagedKVCache(32, PAGE, enable_prefix_cache=True)
    toks = list(range(PAGE * 2 + 5))                   # 2 full pages + tail
    pages_a, cached_a = kv.allocate_with_prefix("a", toks)
    assert cached_a == 0                               # cold
    kv.commit_prefix("a", toks)
    pages_b, cached_b = kv.allocate_with_prefix("b", toks)
    assert cached_b == 2 * PAGE                        # both full pages hit
    assert pages_b[:2] == pages_a[:2]                  # physically shared
    assert pages_b[2] != pages_a[2]                    # partial page private
    assert kv.ref_count(pages_a[0]) == 2
    kv.free("a")
    assert kv.ref_count(pages_b[0]) == 1               # b still owns it
    kv.free("b")
    assert kv.ref_count(pages_b[0]) == 0
    assert kv.cached_free_pages == 2                   # parked in LRU, warm


def test_lru_resurrection_and_eviction():
    kv = PagedKVCache(8, PAGE, enable_prefix_cache=True)   # 7 usable pages
    t1 = list(range(PAGE))                                 # 1 full page
    kv.allocate_with_prefix("a", t1 + [1, 2])
    kv.commit_prefix("a", t1 + [1, 2])
    kv.free("a")
    assert kv.cached_free_pages == 1
    # same prefix returns: resurrect the parked page
    _, cached = kv.allocate_with_prefix("b", t1 + [9, 9])
    assert cached == PAGE
    assert kv.stats["resurrections"] == 1
    kv.free("b")
    # page pressure: allocating more than the plain free list forces LRU
    # eviction, after which the old prefix no longer matches
    kv.allocate("big", 7 * PAGE)
    assert kv.stats["evictions"] >= 1
    kv.free("big")
    _, cached = kv.allocate_with_prefix("c", t1 + [3])
    assert cached == 0                                  # registration evicted


def test_writable_page_cow_semantics():
    kv = PagedKVCache(16, PAGE, enable_prefix_cache=True)
    toks = list(range(PAGE))                            # exactly one page
    pa, _ = kv.allocate_with_prefix("a", toks)
    kv.commit_prefix("a", toks)
    pb, cached = kv.allocate_with_prefix("b", toks)
    assert cached == PAGE - 1                           # final token recomputed
    assert pb == pa                                     # full hit, shared
    cow = kv.writable_page("b", PAGE - 1)
    assert cow is not None
    src, dst = cow
    assert src == pa[0] and dst != src
    assert kv._tables["b"][0] == dst                    # b rewired to its copy
    assert kv.ref_count(src) == 1 and kv.ref_count(dst) == 1
    assert kv.writable_page("b", PAGE - 1) is None      # now exclusive


def test_out_of_pages_still_raises():
    kv = PagedKVCache(4, PAGE, enable_prefix_cache=True)
    kv.allocate("a", 3 * PAGE)
    with pytest.raises(OutOfPages):
        kv.allocate("b", PAGE)


def test_rollback_to_truncates_and_keeps_pages():
    """Speculative truncate-on-reject: lengths shrink, the block table (the
    pages) stays — rejected positions become write headroom again."""
    kv = PagedKVCache(16, PAGE)
    kv.allocate("a", PAGE + 4)
    for _ in range(6):
        kv.append_token("a")
    pages = list(kv._tables["a"])
    v0 = kv.table_version
    kv.rollback_to("a", PAGE + 7)
    assert kv.length("a") == PAGE + 7
    assert kv._tables["a"] == pages
    assert kv.table_version > v0            # device lens must be re-uploaded
    kv.rollback_to("a", PAGE + 7)           # no-op: no version churn
    assert kv.table_version == v0 + 1
    with pytest.raises(AssertionError):
        kv.rollback_to("a", PAGE + 8)       # cannot roll forward


# ---------------------------------------------------------------------------
# end-to-end output equivalence (the real invariant)
# ---------------------------------------------------------------------------

def test_prefix_reuse_outputs_match_cold_start(llama, shared_prefix_prompts,
                                               run_prompts):
    cfg, model, params = llama
    prompts = shared_prefix_prompts(cfg.vocab_size, 6)
    cold, _ = run_prompts(model, params, prompts)
    warm, eng = run_prompts(model, params, prompts,
                            enable_prefix_cache=True)
    assert warm == cold
    assert eng.stats["cached_prompt_tokens"] > 0        # reuse actually fired
    assert eng.cache_stats()["hit_rate"] > 0.3


def test_cow_divergence_outputs_match(llama, run_prompts):
    """Page-aligned identical prompts force the full-prefix-hit + COW path;
    generations diverge afterwards (different seeds via step index) yet must
    match the cold baseline exactly."""
    cfg, model, params = llama
    rng = np.random.default_rng(7)
    p = rng.integers(2, cfg.vocab_size, size=2 * PAGE).tolist()
    prompts = [p, p, p]
    cold, _ = run_prompts(model, params, prompts, max_tokens=6)
    warm, eng = run_prompts(model, params, prompts, max_tokens=6,
                            enable_prefix_cache=True)
    assert warm == cold
    assert eng.cache_stats()["cow_copies"] >= 1


def test_lru_eviction_under_page_pressure_end_to_end(llama, run_prompts):
    cfg, model, params = llama
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab_size, size=2 * PAGE).tolist()
               for _ in range(6)]
    # pool sized for ~2 sequences: later admissions must evict parked pages
    warm, eng = run_prompts(model, params, prompts, max_tokens=4,
                            max_slots=2, num_pages=9,
                            enable_prefix_cache=True)
    cold, _ = run_prompts(model, params, prompts, max_tokens=4,
                          max_slots=2, num_pages=9)
    assert warm == cold
    assert eng.cache_stats()["evictions"] > 0
    assert eng.backend.kv.free_pages == 8               # nothing leaked


@pytest.mark.parametrize("backend", ["paged", "slots"])
def test_chunked_prefill_matches_one_shot(llama, backend, run_prompts):
    cfg, model, params = llama
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (24, 40, 33, 17)]
    one_shot, _ = run_prompts(model, params, prompts, backend=backend)
    chunked, eng = run_prompts(model, params, prompts, backend=backend,
                               chunked_prefill_budget=16)
    assert chunked == one_shot
    # prompts longer than the budget really did span multiple chunks
    assert eng.stats["prefill_chunks"] > len(prompts)


def test_chunked_prefill_with_prefix_cache(llama, shared_prefix_prompts,
                                           run_prompts):
    cfg, model, params = llama
    prompts = shared_prefix_prompts(cfg.vocab_size, 5, seed=11)
    cold, _ = run_prompts(model, params, prompts)
    both, eng = run_prompts(model, params, prompts,
                            enable_prefix_cache=True,
                            chunked_prefill_budget=16)
    assert both == cold
    assert eng.stats["cached_prompt_tokens"] > 0


def test_chunked_prefill_interleaves_decode(llama, engine_factory):
    """While a long prompt ingests chunk-by-chunk, already-running sequences
    keep producing a token every step."""
    cfg, model, params = llama
    rng = np.random.default_rng(9)
    eng = engine_factory(model, params, chunked_prefill_budget=8,
                         max_seq_len=256)
    eng.add_request(InferenceRequest(
        model="m", prompt_tokens=rng.integers(2, cfg.vocab_size,
                                              size=8).tolist(),
        request_id="short", sampling=SamplingParams(max_tokens=32,
                                                    temperature=0.0)))
    eng.step()
    assert "short" in eng.running
    eng.add_request(InferenceRequest(
        model="m", prompt_tokens=rng.integers(2, cfg.vocab_size,
                                              size=64).tolist(),
        request_id="long", sampling=SamplingParams(max_tokens=4,
                                                   temperature=0.0)))
    produced_during_ingest = 0
    steps = 0
    while "long" not in eng.running and steps < 32:
        before = len(eng.running["short"].output_tokens)
        eng.step()
        steps += 1
        if "short" in eng.running:
            produced_during_ingest += \
                len(eng.running["short"].output_tokens) - before
    assert "long" in eng.running or steps < 32
    assert steps >= 64 // 8                 # the ingest really was chunked
    assert produced_during_ingest >= steps - 1   # decode never stalled
    eng.run_to_completion()


def test_sim_engine_prefix_and_chunk_toggles():
    """DES mirror: warm-cache hit rate cuts prefill cost; a chunk budget
    bounds per-step time during a long-prompt admit."""
    from repro.core.clock import EventLoop, VirtualClock
    from repro.core.instances import SimEngine, SimRequest
    from repro.serving.costmodel import InstanceCost
    from repro.core.testbed import LLAMA70B

    def run(hit, budget):
        loop = EventLoop(VirtualClock())
        cost = InstanceCost(cfg=LLAMA70B, chips=8)
        eng = SimEngine(loop, cost, max_slots=8,
                        prefix_cache_hit_rate=hit,
                        chunked_prefill_budget=budget)
        done = []
        for i in range(8):
            eng.submit(SimRequest(f"r{i}", 2048, 16),
                       None, lambda r: done.append(r))
        loop.run_until_idle()
        assert len(done) == 8
        return loop.now(), eng

    t_cold, _ = run(0.0, None)
    t_warm, eng_warm = run(0.9, None)
    assert t_warm < t_cold                   # cache discount helps makespan
    assert eng_warm.total_cached_tokens > 0
    t_chunked, eng_c = run(0.0, 256)
    # same total work either way, so chunking must not LOSE much throughput
    assert t_chunked < t_cold * 1.5
