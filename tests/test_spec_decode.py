"""Speculative decoding: draft-and-verify must be an optimization, never a
different sampler.

The acceptance rule is the seeded-sampler exact-match test (see
repro.serving.sampler): the target's per-position sample is deterministic
given (seed_base, n_gen), so accepted-prefix + residual-resample streams are
token-identical to non-speculative decoding for every sampling mode — which
these tests assert at both acceptance extremes (draft == target: everything
accepted; cold random draft: everything rejected) and for the KV caches
left behind after accept/rollback."""
import numpy as np
import pytest

from repro.serving import backends

PAGE = 16


@pytest.fixture(scope="session")
def cold_draft(lm_factory):
    """Same arch as the target but independently initialized: its proposals
    are (almost) always rejected — the k=0-accepted edge case."""
    _, model, params = lm_factory(seed=99)
    return model, params


@pytest.fixture
def run(engine_factory, run_engine):
    def _run(model, params, reqs, *, draft=None, **cfg_kw):
        eng = engine_factory(model, params, draft=draft, **cfg_kw)
        return run_engine(eng, reqs)
    return _run


# ---------------------------------------------------------------------------
# sampler-level unit behaviour
# ---------------------------------------------------------------------------

def test_spec_accept_counts_prefix_and_residual():
    import jax.numpy as jnp
    from repro.serving.sampler import spec_accept
    targets = jnp.asarray([[5, 6, 7, 8],        # all drafts match -> bonus
                           [5, 9, 7, 8],        # mismatch at j=1
                           [1, 2, 3, 4]])       # mismatch at j=0
    draft = jnp.asarray([[5, 6, 7],
                         [5, 6, 7],
                         [9, 2, 3]])
    emit, n_emit = spec_accept(targets, draft)
    assert n_emit.tolist() == [4, 2, 1]
    assert np.asarray(emit).tolist() == [
        [True, True, True, True],
        [True, True, False, False],
        [True, False, False, False]]


def test_spec_targets_fold_matches_step_seeds():
    """Verify-position j must fold the SAME seed the non-speculative loop
    folds when emitting its (n_gen + j)-th token, so greedy and seeded
    top-p streams stay identical."""
    import jax
    import jax.numpy as jnp
    from repro.serving.sampler import (sample_from_logits, seed_base,
                                      fold_seeds, spec_targets)
    B, T, V = 2, 3, 64
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, T, V))
    temps = jnp.asarray([0.0, 0.9])
    tps = jnp.asarray([1.0, 0.9])
    bases = jnp.asarray([seed_base(3), seed_base(11)], jnp.uint32)
    n_gen = jnp.asarray([4, 9], jnp.int32)
    got = spec_targets(logits, temps, tps, bases, n_gen)
    for j in range(T):
        want = sample_from_logits(logits[:, j], temps, tps,
                                  fold_seeds(bases, n_gen + j))
        assert np.array_equal(np.asarray(got[:, j]), np.asarray(want))


# ---------------------------------------------------------------------------
# token identity at both acceptance extremes
# ---------------------------------------------------------------------------

def test_spec_all_accepted_matches_nonspec(llama, backend, sampling,
                                           request_factory, run):
    """k = all accepted: the draft IS the target, so every proposal
    survives and rounds emit k+1 tokens (accepted prefix + bonus)."""
    cfg, model, params = llama
    kw = dict(max_slots=3, max_seq_len=96, backend=backend, page_size=PAGE)
    reqs = request_factory(cfg.vocab_size, n=3, **sampling)
    ref, _ = run(model, params, reqs, **kw)
    backends.reset_transfer_stats()
    got, eng = run(model, params, reqs, draft=(model, params),
                   spec_tokens=4, **kw)
    assert got == ref
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    assert eng.stats["spec_rounds"] > 0
    assert eng.spec_acceptance_rate() > 0.8
    # accept-heavy rounds emit multiple tokens per sync
    assert eng.stats["decode_syncs"] * 2 < eng.stats["decode_tokens"]


def test_spec_none_accepted_matches_nonspec(llama, cold_draft, backend,
                                            sampling, request_factory, run):
    """k = 0 accepted: a cold random draft disagrees everywhere, every
    round falls back to the single residual-resampled target token — the
    stream must STILL be identical to non-speculative decoding."""
    cfg, model, params = llama
    kw = dict(max_slots=3, max_seq_len=96, backend=backend, page_size=PAGE)
    reqs = request_factory(cfg.vocab_size, n=3, **sampling)
    ref, _ = run(model, params, reqs, **kw)
    got, eng = run(model, params, reqs, draft=cold_draft, spec_tokens=4,
                   **kw)
    assert got == ref
    assert eng.stats["spec_rounds"] > 0
    assert eng.spec_acceptance_rate() < 0.2


def test_spec_stop_token_mid_round(llama, request_factory, run):
    """A stop token landing inside the accepted prefix must truncate the
    round at exactly the same token as the per-step path."""
    cfg, model, params = llama
    kw = dict(max_slots=2, max_seq_len=96, backend="paged", page_size=PAGE)
    samp = dict(max_tokens=24, temperature=0.9, top_p=0.95)
    probe = request_factory(cfg.vocab_size, n=1, **samp)
    ref, _ = run(model, params, probe, **kw)
    toks, reason = ref["r0"]
    assert reason == "length"
    first = {}
    for j, t in enumerate(toks):
        first.setdefault(t, j)
    cands = sorted((j, t) for t, j in first.items()
                   if 2 <= j < 20 and (j + 1) % 5 != 0)
    if not cands:
        cands = sorted((j, t) for t, j in first.items() if j >= 1)
    j0, stop = cands[0]
    reqs = request_factory(cfg.vocab_size, n=2, stop=stop, **samp)
    ref_s, _ = run(model, params, reqs, **kw)
    got_s, eng = run(model, params, reqs, draft=(model, params),
                     spec_tokens=4, **kw)
    assert got_s == ref_s
    assert got_s["r0"][1] == "stop"
    assert len(got_s["r0"][0]) == j0 + 1


def test_spec_draft_resyncs_after_fused_fallback(llama, cold_draft,
                                                 engine_factory,
                                                 request_factory):
    """Staggered arrival: a long prompt admitted mid-stream forces the
    engine through fused-fallback rounds (the draft cache stands still
    while the target advances); when speculation resumes the draft must
    catch up on the emitted tokens it missed — previously this crashed
    with a forward rollback on the paged backend. The small chunk budget
    makes the fallback span exceed k+1 rounds, the worst case."""
    cfg, model, params = llama

    def drive(spec):
        eng = engine_factory(
            model, params, max_slots=4, max_seq_len=128, backend="paged",
            page_size=PAGE, chunked_prefill_budget=8,
            spec_tokens=4 if spec else 0,
            draft=cold_draft if spec else None)
        reqs = request_factory(cfg.vocab_size, n=1, plen=10, max_tokens=30,
                               seed0=0)
        eng.add_request(reqs[0])
        eng.step()
        eng.step()                       # r0 decoding (spec rounds begin)
        late = request_factory(cfg.vocab_size, n=1, plen=70, max_tokens=8,
                               seed0=1, rng_seed=11)[0]
        late.request_id = "late"
        eng.add_request(late)            # 9 chunks of fallback rounds
        outs = eng.run_to_completion()
        return {o.request_id: (o.output_tokens, o.finish_reason)
                for o in outs}, eng

    ref, _ = drive(spec=False)
    got, eng = drive(spec=True)
    assert got == ref
    assert eng.stats["spec_rounds"] > 0


def test_spec_composes_with_chunked_prefill_and_prefix_cache(
        llama, request_factory, run):
    """Speculation must compose with chunked prefill (rounds pause while
    prompts ingest) and prefix caching (shared pages + COW under verify
    writes) without changing outputs."""
    cfg, model, params = llama
    kw = dict(max_slots=3, max_seq_len=128, backend="paged", page_size=PAGE,
              chunked_prefill_budget=24, enable_prefix_cache=True)
    rng = np.random.default_rng(3)
    shared = rng.integers(2, cfg.vocab_size, size=2 * PAGE).tolist()
    prompts = [list(shared), list(shared)] + [
        shared + rng.integers(2, cfg.vocab_size, size=9).tolist()
        for _ in range(3)]
    reqs = request_factory(cfg.vocab_size, prompts=prompts, max_tokens=16)
    ref, er = run(model, params, reqs, **kw)
    got, eg = run(model, params, reqs, draft=(model, params),
                  spec_tokens=4, **kw)
    assert got == ref
    assert eg.cache_stats()["hit_tokens"] == er.cache_stats()["hit_tokens"]
    assert eg.cache_stats()["cow_copies"] >= 1
    assert eg.stats["spec_rounds"] > 0


# ---------------------------------------------------------------------------
# KV caches after accept/rollback == a non-speculative replay
# ---------------------------------------------------------------------------

def _gather_seq_kv(eng, rid):
    """(length, KV rows [0, length)) for one sequence, as numpy — the
    defined cache contents (positions past the length are write headroom:
    masked by every read and rewritten before the length crosses them)."""
    be = eng.backend
    if hasattr(be, "kv"):                               # paged
        table = be.kv._tables[rid]
        n = be.kv.length(rid)
        kp = np.asarray(be.pools["k"])
        vp = np.asarray(be.pools["v"])
        ps = be.page_size
        rows = [np.stack([pool[:, table[p // ps], p % ps]
                          for p in range(n)], 1) for pool in (kp, vp)]
        return n, rows
    s = be.slot(rid)                                    # dense slots
    n = int(np.asarray(be.cache["len"])[s])
    return n, [np.asarray(be.cache[c])[:, s, :, :n] for c in ("k", "v")]


@pytest.mark.parametrize("backend", ["paged", "slots"])
def test_spec_rollback_leaves_kv_as_nonspec_replay(llama, cold_draft,
                                                   backend, engine_factory,
                                                   request_factory):
    """Mid-generation, a speculating engine's per-sequence KV (including
    COW'd shared pages from prefix-cache hits) must equal a non-speculative
    engine replayed to the same per-sequence token counts: byte-identical
    on the paged backend (verify and decode share the attention
    formulation); on the dense backend the batched verify attention
    reassociates float32 sums vs the appended-decode read path, so rows
    match to 1e-5 while lengths and token streams stay exactly equal."""
    cfg, model, params = llama
    kw = dict(max_slots=3, max_seq_len=128, page_size=PAGE, backend=backend)
    if backend == "paged":
        kw["enable_prefix_cache"] = True
    rng = np.random.default_rng(3)
    shared = rng.integers(2, cfg.vocab_size, size=2 * PAGE).tolist()
    prompts = [list(shared), list(shared),
               shared + rng.integers(2, cfg.vocab_size, size=7).tolist()]
    reqs = request_factory(cfg.vocab_size, prompts=prompts, max_tokens=40)

    es = engine_factory(model, params, draft=cold_draft, spec_tokens=4,
                        **kw)
    for r in reqs:
        es.add_request(r)
    for _ in range(6):                   # stop mid-flight, caches still live
        es.step()
    assert es.running and es.stats["spec_rounds"] > 0
    want = {rid: list(run.output_tokens)
            for rid, run in es.running.items()}
    spec_kv = {rid: _gather_seq_kv(es, rid) for rid in es.running}

    en = engine_factory(model, params, **kw)
    for r in request_factory(cfg.vocab_size, prompts=prompts,
                             max_tokens=40):
        en.add_request(r)
    got = {}
    for _ in range(100):
        if len(got) == len(want):
            break
        en.step()
        for rid, run in en.running.items():
            if rid in want and rid not in got \
                    and len(run.output_tokens) == len(want[rid]):
                assert run.output_tokens == want[rid]
                got[rid] = _gather_seq_kv(en, rid)
    assert set(got) == set(want)
    for rid in want:
        n_s, kv_s = spec_kv[rid]
        n_r, kv_r = got[rid]
        assert n_s == n_r
        for a, b in zip(kv_s, kv_r):
            if backend == "paged":
                assert np.array_equal(a, b), f"{rid}: paged KV diverged"
            else:
                np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# DES mirror: SimEngine speculative rounds
# ---------------------------------------------------------------------------

def test_expected_spec_tokens():
    from repro.serving.costmodel import expected_spec_tokens
    assert expected_spec_tokens(0.0, 4) == 1.0          # nothing accepted
    assert expected_spec_tokens(1.0, 4) == 5.0          # everything + bonus
    mid = expected_spec_tokens(0.7, 4)
    assert 1.0 < mid < 5.0
    assert expected_spec_tokens(0.7, 8) > mid           # deeper drafts help


def test_sim_engine_spec_decode_mirror():
    from repro.configs import REGISTRY
    from repro.core.clock import EventLoop, VirtualClock
    from repro.core.instances import SimEngine, SimRequest
    from repro.serving.costmodel import InstanceCost

    target = InstanceCost(cfg=REGISTRY["yi-34b"], chips=8)
    draft = InstanceCost(cfg=REGISTRY["llama3.2-3b"], chips=8)

    def run(spec_k, accept=0.8):
        loop = EventLoop(VirtualClock())
        done = []
        eng = SimEngine(loop, target, max_slots=4, spec_tokens=spec_k,
                        spec_accept_rate=accept, draft_cost=draft)
        for i in range(4):
            eng.submit(SimRequest(f"r{i}", 64, 48), None, done.append)
        loop.run_until_idle()
        assert len(done) == 4
        return loop.now(), sorted((d["request_id"], d["output_tokens"])
                                  for d in done)

    t0, done0 = run(0)
    t_spec, done_spec = run(4, accept=0.8)
    assert done0 == done_spec            # same tokens per request
    assert t_spec < t0                   # accept-heavy rounds win
    t_cold, _ = run(4, accept=0.0)
    assert t_cold > t_spec               # nothing accepted: rounds cost more
    # and the closed-form throughput agrees on direction
    assert target.spec_decode_tok_per_s(4, draft, 4, 0.8) > \
        target.decode_tok_per_s(4)
    with pytest.raises(ValueError):
        SimEngine(EventLoop(), target, spec_tokens=4)    # draft required


def test_spec_requires_draft_and_attention_family(llama, mamba):
    from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
    _, model, params = llama
    with pytest.raises(ValueError, match="draft"):
        ContinuousBatchingEngine(model, params,
                                 EngineConfig(spec_tokens=4))
    _, smodel, sparams = mamba
    with pytest.raises(ValueError, match="attention"):
        ContinuousBatchingEngine(smodel, sparams,
                                 EngineConfig(spec_tokens=4),
                                 draft_model=smodel, draft_params=sparams)