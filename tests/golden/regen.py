"""Regenerate the /v1 golden schema fixtures.

Run ``PYTHONPATH=src python tests/golden/regen.py`` after a DELIBERATE
contract change; the diff of these files IS the wire-format change review.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from repro.api import schemas                          # noqa: E402
from test_api_schemas import schema_examples           # noqa: E402


def main():
    out = pathlib.Path(__file__).parent
    for name, obj in schema_examples().items():
        path = out / f"{name}.json"
        path.write_text(schemas.dumps(obj) + "\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
